"""GFJS storage roundtrip + compute-and-reuse scenario tests."""

import os

import numpy as np
import pytest

from repro.core.api import GraphicalJoin
from repro.core.gfjs import desummarize, row_at
from repro.core.storage import gfjs_to_csv, load_gfjs, save_gfjs
from repro.relational.synth import figure1, lastfm_like


def test_save_load_roundtrip(tmp_path):
    cat, query = figure1()
    gj = GraphicalJoin(cat, query)
    gfjs = gj.run()
    p = str(tmp_path / "fig1.gfjs")
    nbytes = gj.store(gfjs, p)
    assert nbytes > 0 and os.path.getsize(p) == nbytes

    back = load_gfjs(p)
    assert back.join_size == gfjs.join_size
    assert back.column_order == gfjs.column_order
    for a, b in zip(gfjs.levels, back.levels):
        assert a.vars == b.vars
        assert np.array_equal(a.freq, b.freq)
        for v in a.vars:
            assert np.array_equal(a.key_cols[v], b.key_cols[v])
    # desummarize from the loaded summary == from the fresh one
    fa = desummarize(gfjs)
    fb = desummarize(back)
    for v in gfjs.column_order:
        assert np.array_equal(fa[v], fb[v])


def test_compute_and_reuse_end_to_end(tmp_path):
    """The paper's second scenario: summarize -> store -> load -> expand."""
    cat, queries = lastfm_like(n_users=120, n_artists=100,
                               artists_per_user=5, friends_per_user=3)
    q = queries["lastfm_A1"]
    gj = GraphicalJoin(cat, q)
    gfjs = gj.run()
    p = str(tmp_path / "a1.gfjs")
    stored = gj.store(gfjs, p)
    back = GraphicalJoin.load(p)
    res = desummarize(back, decode=False)
    assert len(res[back.column_order[0]]) == back.join_size
    # summary on disk is smaller than the flat result in memory
    flat_bytes = sum(v.nbytes for v in res.values())
    assert stored < flat_bytes


def test_csv_export_matches_paper_format(tmp_path):
    cat, query = figure1()
    gj = GraphicalJoin(cat, query, elimination_order=["D", "C", "B", "A"])
    gfjs = gj.run()
    total = gfjs_to_csv(gfjs, str(tmp_path / "csvs"))
    assert total > 0
    with open(tmp_path / "csvs" / "A.csv") as f:
        assert f.read().strip() == "a3,32"


def test_row_at_random_access():
    cat, query = figure1()
    gj = GraphicalJoin(cat, query)
    gfjs = gj.run()
    flat = gj.desummarize(gfjs)
    for t in [0, 1, 7, 15, 31]:
        row = row_at(gfjs, t)
        for v in gfjs.column_order:
            assert row[v] == flat[v][t]
    with pytest.raises(IndexError):
        row_at(gfjs, 32)
