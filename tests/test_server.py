"""JoinServer unit + integration tests (ISSUE 8): single-flight request
collapsing, batched per-key probes, admission control, deadlines, and the
trace/metrics surface — every answer bit-identical to a direct
JoinService call."""

import threading
import time

import numpy as np
import pytest

from repro.obs.check import validate
from repro.obs.trace import Tracer
from repro.relational.query import JoinQuery
from repro.relational.synth import lastfm_like
from repro.relational.table import Catalog, Table
from repro.serve.server import (AdmissionRejected, DeadlineExceeded,
                                JoinServer, SingleFlight, lookup_rows)
from repro.summary.service import JoinService


@pytest.fixture(scope="module")
def lastfm():
    return lastfm_like(n_users=50, n_artists=40, artists_per_user=4,
                       friends_per_user=3)


def _gate_frames(svc, entered=None, release=None):
    """Intercept ``svc.frame`` with an entered/release gate + call count.

    Instance-attribute shadowing, so only this service is affected and
    ``calls`` counts *service-level* builds — the thing the collapse
    invariant bounds.
    """
    orig = svc.frame
    calls = []

    def gated(query, plan=None):
        calls.append(query.name)
        if entered is not None:
            entered.set()
        if release is not None:
            assert release.wait(10.0), "gate never released"
        return orig(query, plan=plan)

    svc.frame = gated
    return calls


# -- SingleFlight unit ------------------------------------------------------

def test_single_flight_collapses_and_shares_result():
    sf = SingleFlight()
    entered, release = threading.Event(), threading.Event()
    builds, results = [], []

    def build(_fl):
        builds.append(1)
        entered.set()
        release.wait(5.0)
        return "value"

    def leader():
        results.append(sf.do("k", build))

    def waiter():
        entered.wait(5.0)
        results.append(sf.do("k", build))

    ts = [threading.Thread(target=leader)] + \
        [threading.Thread(target=waiter) for _ in range(4)]
    for t in ts:
        t.start()
    entered.wait(5.0)
    while sum(fl.waiters for fl in sf._flights.values()) < 4:
        time.sleep(0.001)
    release.set()
    for t in ts:
        t.join()
    assert len(builds) == 1
    assert {v for v, _, _ in results} == {"value"}
    assert sorted(lead for _, lead, _ in results) == [False] * 4 + [True]
    # flight table drains: a later call starts a fresh flight
    assert sf.inflight() == 0
    v, lead, _ = sf.do("k", lambda _fl: "again")
    assert v == "again" and lead


def test_single_flight_propagates_leader_error_to_waiters():
    sf = SingleFlight()
    entered, release = threading.Event(), threading.Event()
    errors = []

    def build(_fl):
        entered.set()
        release.wait(5.0)
        raise ValueError("boom")

    def leader():
        try:
            sf.do("k", build)
        except ValueError as e:
            errors.append(e)

    def waiter():
        entered.wait(5.0)
        try:
            sf.do("k", lambda _fl: "never")
        except ValueError as e:
            errors.append(e)

    ts = [threading.Thread(target=leader), threading.Thread(target=waiter)]
    ts[0].start()
    entered.wait(5.0)
    ts[1].start()
    while sum(fl.waiters for fl in sf._flights.values()) < 1:
        time.sleep(0.001)
    release.set()
    for t in ts:
        t.join()
    assert len(errors) == 2
    assert all(str(e) == "boom" for e in errors)


def test_single_flight_wait_timeout():
    sf = SingleFlight()
    entered, release = threading.Event(), threading.Event()

    def leader():
        sf.do("k", lambda _fl: (entered.set(), release.wait(5.0))[0])

    t = threading.Thread(target=leader)
    t.start()
    entered.wait(5.0)
    with pytest.raises(DeadlineExceeded):
        sf.do("k", lambda _fl: "never", timeout=0.05)
    release.set()
    t.join()


# -- lookup_rows ------------------------------------------------------------

def test_lookup_rows_matches_table_and_zeros_missing():
    table = {"U": np.asarray([2, 5, 9]),
             "n": np.asarray([10.0, 20.0, 30.0]),
             "s": np.asarray([1.5, 2.5, 3.5])}
    out = lookup_rows(table, "U", ["n", "s"], np.asarray([5, 1, 9, 2, 99]))
    np.testing.assert_allclose(out, [[20.0, 2.5], [0.0, 0.0], [30.0, 3.5],
                                     [10.0, 1.5], [0.0, 0.0]])
    assert out.dtype == np.float32
    empty = lookup_rows({"U": np.asarray([]), "n": np.asarray([])},
                        "U", ["n"], np.asarray([1, 2]))
    np.testing.assert_allclose(empty, [[0.0], [0.0]])


# -- request collapsing -----------------------------------------------------

def test_frame_equals_direct_service(lastfm):
    cat, qs = lastfm
    q = qs["lastfm_A1"]
    server = JoinServer(JoinService(cat))
    want = JoinService(cat).frame(q)
    got = server.frame(q)
    assert got.frame.count() == want.frame.count()
    np.testing.assert_array_equal(got.frame.weights[0],
                                  want.frame.weights[0])


def test_cold_stampede_collapses_to_one_build(lastfm):
    """The tentpole invariant: 16 racers -> exactly 1 service build,
    1 "computed" reply, 15 "collapsed" replies, all bit-identical."""
    cat, qs = lastfm
    svc = JoinService(cat)
    server = JoinServer(svc)
    q = qs["lastfm_B"]
    plan = svc.compile(q)           # pre-compile: the race is on the build
    entered, release = threading.Event(), threading.Event()
    calls = _gate_frames(svc, entered, release)

    N = 16
    replies, errors = [None] * N, []

    def worker(i):
        try:
            replies[i] = server.frame(q, plan=plan)
        except Exception as e:  # pragma: no cover
            errors.append(e)

    ts = [threading.Thread(target=worker, args=(i,)) for i in range(N)]
    ts[0].start()
    assert entered.wait(10.0)
    for t in ts[1:]:
        t.start()
    # every non-leader must be parked on the latch before the build runs
    while sum(fl.waiters
              for fl in server._flights._flights.values()) < N - 1:
        time.sleep(0.001)
    release.set()
    for t in ts:
        t.join()

    assert not errors
    assert calls == [q.name]                     # exactly one service build
    sources = sorted(r.source for r in replies)
    assert sources.count("computed") == 1
    assert sources.count("collapsed") == N - 1
    assert {r.key for r in replies} == {replies[0].key}
    ref = replies[0].frame
    for r in replies:
        assert r.frame.count() == replies[0].frame.count()
        for a, b in zip(r.frame.weights, ref.weights):
            np.testing.assert_array_equal(a, b)  # same build: bit-identical
    st = server.stats()
    assert st["requests"] == N and st["collapsed"] == N - 1
    assert st["inflight"] == 0


def test_waiter_deadline_expiry_is_clean(lastfm):
    """Waiters whose deadline expires get DeadlineExceeded — never a
    partial frame; the leader still completes."""
    cat, qs = lastfm
    svc = JoinService(cat)
    server = JoinServer(svc)
    q = qs["lastfm_tri"]
    plan = svc.compile(q)
    entered, release = threading.Event(), threading.Event()
    _gate_frames(svc, entered, release)

    leader_reply, waiter_errs = [], []

    def leader():
        leader_reply.append(server.frame(q, plan=plan))

    def waiter():
        try:
            server.frame(q, plan=plan, deadline=0.05)
        except DeadlineExceeded as e:
            waiter_errs.append(e)

    tl = threading.Thread(target=leader)
    tl.start()
    assert entered.wait(10.0)
    tw = [threading.Thread(target=waiter) for _ in range(3)]
    for t in tw:
        t.start()
    for t in tw:
        t.join()                    # expire while the leader is gated
    release.set()
    tl.join()

    assert len(waiter_errs) == 3
    assert all(isinstance(e, TimeoutError) for e in waiter_errs)
    assert leader_reply[0].source == "computed"
    assert server.stats()["deadline_expired"] == 3


# -- batched probes ---------------------------------------------------------

def test_lookup_matches_direct_group_by(lastfm):
    cat, qs = lastfm
    q = qs["lastfm_A1"]
    svc = JoinService(cat)
    server = JoinServer(svc)
    aggs = {"n": "count", "s": ("sum", "A1")}
    direct = JoinService(cat).frame(q).frame.group_by(["U1"], **aggs)
    uniq = np.asarray(direct["U1"])
    keys = np.concatenate([uniq[:7], np.asarray([10 ** 9])])  # + a miss
    rows = server.lookup(q, "U1", keys, aggs)
    assert rows.shape == (8, 2)
    np.testing.assert_allclose(rows[:7, 0], np.asarray(direct["n"][:7],
                                                       np.float32))
    np.testing.assert_allclose(rows[:7, 1], np.asarray(direct["s"][:7],
                                                       np.float32))
    np.testing.assert_allclose(rows[7], [0.0, 0.0])
    # resident table: the second probe re-pulls nothing
    server.lookup(q, "U1", keys, aggs)
    assert server.stats()["table_recomputes"] == 1


def test_concurrent_probes_batch_into_one_lookup(lastfm):
    """Followers arriving while the leader resolves the table are answered
    by the leader's single vectorized lookup."""
    cat, qs = lastfm
    svc = JoinService(cat)
    server = JoinServer(svc)
    q = qs["lastfm_B"]
    plan = svc.compile(q)
    aggs = {"n": "count"}
    direct = JoinService(cat).frame(q).frame.group_by(["U1"], **aggs)
    uniq = np.asarray(direct["U1"])
    entered, release = threading.Event(), threading.Event()
    _gate_frames(svc, entered, release)

    outs, errors = {}, []

    def prober(i):
        try:
            ks = uniq[i:i + 3]
            outs[i] = (ks, server.lookup(q, "U1", ks, aggs, plan=plan))
        except Exception as e:  # pragma: no cover
            errors.append(e)

    ts = [threading.Thread(target=prober, args=(i,)) for i in range(4)]
    ts[0].start()
    assert entered.wait(10.0)       # leader parked inside the table build
    for t in ts[1:]:
        t.start()
    while sum(len(b.pending) for b in server._batchers.values()) < 3:
        time.sleep(0.001)
    release.set()
    for t in ts:
        t.join()

    assert not errors
    for i, (ks, rows) in outs.items():
        pos = np.searchsorted(uniq, ks)
        np.testing.assert_allclose(
            rows[:, 0], np.asarray(direct["n"], np.float32)[pos])
    st = server.stats()
    assert st["probes"] == 1               # ONE vectorized lookup
    assert st["batched"] == 3              # followers served from the batch
    assert st["table_recomputes"] == 1


def test_lookup_sees_appends(lastfm):
    """The resident table is keyed on content versions: an append mints a
    new table and probes reflect the grown catalog."""
    rng = np.random.default_rng(3)
    t = Table("events", {"x0": rng.integers(0, 5, 40).astype(np.int64),
                         "x1": rng.integers(0, 5, 40).astype(np.int64)})
    q = JoinQuery.of("events_q", [("events", {"x0": "A", "x1": "B"})])
    svc = JoinService(Catalog.of(t))
    server = JoinServer(svc)
    keys = np.arange(5)
    before = server.lookup(q, "A", keys, {"n": "count"})
    svc.append("events", {"x0": np.zeros(6, np.int64),
                          "x1": np.ones(6, np.int64)})
    after = server.lookup(q, "A", keys, {"n": "count"})
    assert after[0, 0] == before[0, 0] + 6
    assert server.stats()["table_recomputes"] == 2


# -- admission control ------------------------------------------------------

def test_admission_rejects_expensive_cold_build(lastfm):
    cat, qs = lastfm
    svc = JoinService(cat)
    q = qs["lastfm_A1"]
    plan = svc.compile(q)
    assert plan.admission_cost() > 0.0
    server = JoinServer(svc, cost_ceiling=plan.admission_cost() / 2)
    with pytest.raises(AdmissionRejected):
        server.frame(q, plan=plan)
    assert server.stats()["rejected"] == 1
    # warm via the raw service: the hit path is never admission-gated
    svc.frame(q, plan=plan)
    assert server.frame(q, plan=plan).source == "memory"


def test_admission_passes_cheap_and_unceilinged(lastfm):
    cat, qs = lastfm
    q = qs["lastfm_B"]
    svc = JoinService(cat)
    plan = svc.compile(q)
    assert JoinServer(svc).frame(q, plan=plan).source == "computed"
    svc2 = JoinService(cat)
    plan2 = svc2.compile(q)
    server = JoinServer(svc2, cost_ceiling=plan2.admission_cost() * 10)
    assert server.frame(q, plan=plan2).source == "computed"
    assert server.stats()["rejected"] == 0


def test_admission_queue_deadline(lastfm):
    cat, qs = lastfm
    svc = JoinService(cat)
    q = qs["lastfm_tri"]
    plan = svc.compile(q)
    server = JoinServer(svc, cost_ceiling=plan.admission_cost() / 2,
                        admission="queue", max_expensive_builds=1)
    server._build_slots.acquire()           # occupy the only build slot
    try:
        with pytest.raises(DeadlineExceeded):
            server.frame(q, plan=plan, deadline=0.1)
        assert server.stats()["deadline_expired"] == 1
        assert server.stats()["queue_depth"] == 0   # gauge unwound
    finally:
        server._build_slots.release()
    reply = server.frame(q, plan=plan, deadline=30.0)
    assert reply.source == "computed"       # slot free: queued build runs


def test_admission_queue_skips_refreshable_miss():
    """A refreshable miss is O(delta): it must pass the ceiling free."""
    rng = np.random.default_rng(4)
    t = Table("events", {"x0": rng.integers(0, 5, 40).astype(np.int64),
                         "x1": rng.integers(0, 5, 40).astype(np.int64)})
    q = JoinQuery.of("events_q", [("events", {"x0": "A", "x1": "B"})])
    svc = JoinService(Catalog.of(t))
    plan = svc.compile(q)
    svc.frame(q, plan=plan)                 # retain incremental state
    svc.append("events", {"x0": np.asarray([1], np.int64),
                          "x1": np.asarray([2], np.int64)})
    assert svc.can_refresh(q, plan)
    server = JoinServer(svc, cost_ceiling=plan.admission_cost() / 2)
    reply = server.frame(q, plan=plan)      # miss, but never rejected
    assert reply.source == "refreshed"


def test_server_constructor_validation(lastfm):
    cat, _ = lastfm
    svc = JoinService(cat)
    with pytest.raises(ValueError):
        JoinServer(svc, admission="maybe")
    with pytest.raises(ValueError):
        JoinServer(svc, max_expensive_builds=0)
    with pytest.raises(ValueError):
        JoinServer(svc, batch_window=-1.0)
    with pytest.raises(ValueError):
        JoinServer(svc, table_byte_budget=0)


def test_resident_tables_byte_bounded(lastfm):
    """The resident group-by LRU is bounded by bytes, not just entries —
    defaulting to the service's SummaryCache byte budget."""
    cat, qs = lastfm
    svc = JoinService(cat)
    assert JoinServer(svc).table_byte_budget == svc.cache.byte_budget

    server = JoinServer(svc, table_byte_budget=1)   # evict-everything budget
    q = qs["lastfm_A1"]
    keys = np.asarray([0, 1, 2])
    server.lookup(q, "U1", keys, {"n": "count"})
    first_bytes = server.stats()["resident_table_bytes"]
    assert first_bytes > 0                           # the newest entry stays
    assert server.stats()["resident_tables"] == 1
    # a second distinct table evicts the first (over byte budget)
    server.lookup(q, "U1", keys, {"n": "count", "s": ("sum", "A1")})
    st = server.stats()
    assert st["resident_tables"] == 1
    assert st["resident_table_bytes"] > 0
    assert st["table_recomputes"] == 2
    # the evicted table rebuilds on re-probe
    server.lookup(q, "U1", keys, {"n": "count"})
    assert server.stats()["table_recomputes"] == 3
    from repro.obs.metrics import REGISTRY
    assert REGISTRY.gauge("server.resident_table_bytes",
                          unit="B").value > 0


def test_resident_tables_entry_bound_still_applies(lastfm):
    cat, qs = lastfm
    svc = JoinService(cat)
    server = JoinServer(svc, max_tables=1, table_byte_budget=1 << 30)
    q = qs["lastfm_A1"]
    keys = np.asarray([0, 1])
    server.lookup(q, "U1", keys, {"n": "count"})
    server.lookup(q, "U1", keys, {"n": "count", "s": ("sum", "A1")})
    st = server.stats()
    assert st["resident_tables"] == 1
    # resident bytes track exactly the surviving entry
    assert st["resident_table_bytes"] == \
        sum(np.asarray(v).nbytes
            for v in server._tables[next(iter(server._tables))].values())


# -- observability ----------------------------------------------------------

def test_server_trace_validates_with_expect_server(lastfm):
    cat, qs = lastfm
    svc = JoinService(cat)
    tracer = Tracer()
    server = JoinServer(svc, tracer=tracer)
    q = qs["lastfm_A1"]
    plan = svc.compile(q)
    entered, release = threading.Event(), threading.Event()
    _gate_frames(svc, entered, release)

    def leader():
        server.frame(q, plan=plan)

    def waiter():
        entered.wait(10.0)
        server.frame(q, plan=plan)

    tl = threading.Thread(target=leader)
    tw = threading.Thread(target=waiter)
    tl.start()
    assert entered.wait(10.0)
    tw.start()
    while sum(fl.waiters
              for fl in server._flights._flights.values()) < 1:
        time.sleep(0.001)
    release.set()
    tl.join()
    tw.join()
    server.lookup(q, "U1", np.asarray([1, 2, 3]), {"n": "count"}, plan=plan)

    reqs = tracer.find("server:request")
    builds = tracer.find("server:build")
    # 2 frame racers + 1 lookup + the lookup's internal frame pull
    assert len([s for s in reqs if s.args["kind"] == "frame"]) == 3
    assert len([s for s in reqs if s.args["kind"] == "lookup"]) == 1
    assert builds, "leader opened no server:build span"
    assert all("source" in s.args for s in reqs)
    collapsed = [s for s in reqs if s.args.get("collapsed")]
    assert len(collapsed) == 1
    # the latch handoff is recorded: waiter's span links the leader's build
    assert collapsed[0].args["build_span_id"] in {b.span_id for b in builds}
    doc = tracer.to_chrome_trace()
    assert validate(doc, expect_server=True) == []

    # the validator actually bites: strip sources and it must complain
    for ev in doc["traceEvents"]:
        if ev.get("name") == "server:request":
            ev["args"].pop("source", None)
    assert any("source" in e for e in validate(doc, expect_server=True))
    assert any("server:request" in e
               for e in validate({"traceEvents": [
                   {"name": "x", "ph": "X", "ts": 0, "dur": 1,
                    "pid": 1, "tid": 1}]}, expect_server=True))


def test_server_metrics_registry_mirrors(lastfm):
    from repro.obs.metrics import REGISTRY
    cat, qs = lastfm
    svc = JoinService(cat)
    server = JoinServer(svc)
    q = qs["lastfm_B"]
    before = REGISTRY.counter("server.requests").value
    server.frame(q)
    server.frame(q)
    assert REGISTRY.counter("server.requests").value - before == 2
