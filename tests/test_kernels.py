"""Pallas kernel validation: interpret-mode execution vs pure-jnp oracles,
swept across shapes and dtypes (per the repo's kernel contract)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need it; absent in minimal envs
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.expand import expand_gather
from repro.kernels.segsum import mul_segsum as segsum_kernel
from repro.kernels.boundaries import run_boundaries as boundaries_kernel
from repro.kernels.dense_contract import dense_message as dense_kernel


# ---------------------------------------------------------------------------
# expand_gather (RLE desummarization / frontier expansion)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_runs", [1, 7, 500, 513, 2048])
@pytest.mark.parametrize("payload_dtype", [jnp.int32, jnp.float32])
def test_expand_gather_shapes(n_runs, payload_dtype):
    rng = np.random.default_rng(n_runs)
    freqs = rng.integers(1, 9, size=n_runs)
    bounds = np.cumsum(freqs).astype(np.int32)
    total = int(bounds[-1])
    payload = jnp.asarray(rng.integers(0, 1 << 20, n_runs), payload_dtype)
    t_pad = ops.next_bucket(total)
    got = expand_gather(payload, jnp.asarray(bounds), t_pad=t_pad, interpret=True)
    want = ref.expand_gather_ref(payload, jnp.asarray(bounds), total)
    np.testing.assert_allclose(np.asarray(got[:total]), np.asarray(want))


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(1, 12), min_size=1, max_size=300), st.integers(0, 2**31 - 1))
def test_expand_gather_property(freqs, seed):
    rng = np.random.default_rng(seed)
    bounds = np.cumsum(freqs).astype(np.int32)
    total = int(bounds[-1])
    payload = jnp.asarray(rng.integers(0, 1 << 30, len(freqs)), jnp.int32)
    got = ops.rle_expand(payload, jnp.asarray(bounds), total, interpret=True)
    want = np.repeat(np.asarray(payload), freqs)
    np.testing.assert_array_equal(np.asarray(got), want)


def test_expand_indices_matches_numpy_repeat():
    freqs = np.asarray([3, 1, 4, 1, 5, 9, 2, 6])
    bounds = np.cumsum(freqs).astype(np.int32)
    got = ops.expand_indices(jnp.asarray(bounds), int(bounds[-1]), interpret=True)
    want = np.repeat(np.arange(len(freqs)), freqs)
    np.testing.assert_array_equal(np.asarray(got), want)


# ---------------------------------------------------------------------------
# mul_segsum (message passing sum half)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,segs", [(1, 1), (100, 3), (512, 512), (1500, 40), (4096, 1000)])
def test_mul_segsum_shapes(n, segs):
    rng = np.random.default_rng(n)
    # dense sorted ids covering all segs
    seg = np.sort(np.concatenate([np.arange(segs), rng.integers(0, segs, max(n - segs, 0))]))[:n]
    seg = np.sort(seg).astype(np.int32)
    # re-densify in case truncation dropped the tail segments
    _, seg = np.unique(seg, return_inverse=True)
    segs_eff = int(seg.max()) + 1
    x = rng.integers(0, 100, n).astype(np.float32)
    y = rng.integers(0, 100, n).astype(np.float32)
    got = segsum_kernel(jnp.asarray(seg, jnp.int32), jnp.asarray(x), jnp.asarray(y),
                        num_segments=segs_eff, interpret=True)
    want = ref.mul_segsum_ref(jnp.asarray(seg, jnp.int32), jnp.asarray(x),
                              jnp.asarray(y), segs_eff)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(1, 6), min_size=1, max_size=200), st.integers(0, 2**31 - 1))
def test_mul_segsum_property(run_lengths, seed):
    rng = np.random.default_rng(seed)
    seg = np.repeat(np.arange(len(run_lengths)), run_lengths).astype(np.int32)
    n = len(seg)
    x = rng.integers(0, 50, n).astype(np.float32)
    y = rng.integers(0, 50, n).astype(np.float32)
    got = ops.mul_segsum(seg, x, y, len(run_lengths), interpret=True)
    want = ref.mul_segsum_ref(jnp.asarray(seg), jnp.asarray(x), jnp.asarray(y),
                              len(run_lengths))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# run_boundaries (GROUP BY build)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 5, 1024, 1025, 5000])
def test_run_boundaries_shapes(n):
    rng = np.random.default_rng(n)
    keys = np.sort(rng.integers(0, max(n // 3, 1), n)).astype(np.int32)
    got = boundaries_kernel(jnp.asarray(keys), interpret=True)
    want = ref.run_boundaries_ref(jnp.asarray(keys))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(0, 9), min_size=1, max_size=400))
def test_run_boundaries_property(vals):
    keys = np.sort(np.asarray(vals, dtype=np.int32))
    got = ops.run_boundaries(keys, interpret=True)
    want = ref.run_boundaries_ref(jnp.asarray(keys))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_group_by_count_composition():
    keys = np.sort(np.random.default_rng(0).integers(0, 50, 3000)).astype(np.int32)
    seg, counts, num = ops.group_by_count(keys, interpret=True)
    uniq, want = np.unique(keys, return_counts=True)
    assert num == len(uniq)
    np.testing.assert_allclose(np.asarray(counts), want.astype(np.float32))


# ---------------------------------------------------------------------------
# dense_message (counting-semiring MXU matmul)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("P,V,K", [(1, 1, 1), (128, 128, 1), (300, 257, 5),
                                   (256, 512, 128), (513, 100, 130)])
def test_dense_message_shapes(P, V, K):
    rng = np.random.default_rng(P * V + K)
    phi = rng.integers(0, 100, (P, V)).astype(np.float32)
    m = rng.integers(0, 100, (V, K)).astype(np.float32)
    got = dense_kernel(jnp.asarray(phi), jnp.asarray(m), interpret=True)
    want = ref.dense_message_ref(jnp.asarray(phi), jnp.asarray(m))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 80), st.integers(1, 80), st.integers(1, 8),
       st.integers(0, 2**31 - 1))
def test_dense_message_property(P, V, K, seed):
    rng = np.random.default_rng(seed)
    phi = rng.integers(0, 9, (P, V)).astype(np.float32)
    m = rng.integers(0, 9, (V, K)).astype(np.float32)
    got = ops.dense_message(phi, m, interpret=True)
    want = np.asarray(phi @ m)
    np.testing.assert_allclose(np.asarray(got), want)
