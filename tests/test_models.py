"""Per-architecture smoke tests (reduced configs, CPU) + model-stack
correctness properties (flash==dense, prefill/decode==forward)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke
from repro.models import flash
from repro.models.model import LM

# depth tier (DESIGN.md §13): deselect with -m "not slow"
pytestmark = pytest.mark.slow

rng = np.random.default_rng(0)


def _batch(cfg, B=2, S=32):
    if cfg.family == "audio":
        b = {"frames": jnp.asarray(rng.normal(size=(B, S, 512)), jnp.float32)}
    else:
        b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}
    b["labels"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    if cfg.family == "vlm":
        b["vision"] = jnp.asarray(
            rng.normal(size=(B, cfg.vlm.num_image_tokens, cfg.vlm.vision_dim)),
            jnp.float32)
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch):
    """One forward + one SGD-style step on a reduced config: correct output
    shape, finite loss, no NaNs, loss changes after an update."""
    cfg = get_smoke(arch)
    lm = LM(cfg)
    p = lm.init(jax.random.key(0))
    batch = _batch(cfg)
    logits = jax.jit(lm.forward)(p, batch)
    assert logits.shape == (2, 32, lm.vocab_padded)
    assert not bool(jnp.isnan(logits).any())
    loss, grads = jax.jit(jax.value_and_grad(lm.loss))(p, batch)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32)))) for g in grads.values())
    assert np.isfinite(gn) and gn > 0
    p2 = jax.tree.map(lambda a, g: (a.astype(jnp.float32)
                                    - 1e-2 * g.astype(jnp.float32)).astype(a.dtype),
                      p, grads)
    loss2 = jax.jit(lm.loss)(p2, batch)
    assert float(loss2) != float(loss)


@pytest.mark.parametrize("arch", ["qwen3_8b", "zamba2_2p7b", "xlstm_350m",
                                  "deepseek_v2_236b"])
def test_prefill_decode_matches_forward(arch):
    """Teacher-forced forward logits == prefill+decode logits stepwise.

    f32 compute so the check isolates algorithmic consistency (e.g. MLA's
    absorbed decode vs expanded prefill) from bf16 rounding.  MoE capacity
    is raised to drop-free: capacity-based dropping legitimately differs
    between teacher-forced and incremental token counts."""
    cfg = get_smoke(arch).scaled(compute_dtype="float32",
                                 param_dtype="float32")
    if cfg.moe is not None:
        cfg = cfg.scaled(moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    lm = LM(cfg)
    p = lm.init(jax.random.key(1))
    B, S = 2, 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    full = lm.forward(p, {"tokens": toks})              # [B,S,V]

    k = S // 2
    logits, caches = lm.prefill(p, {"tokens": toks[:, :k]}, s_max=S)
    np.testing.assert_allclose(np.asarray(logits[:, 0]), np.asarray(full[:, k - 1]),
                               rtol=2e-2, atol=2e-2)
    for t in range(k, S):
        logits, caches = lm.decode_step(p, toks[:, t:t + 1], caches)
        np.testing.assert_allclose(np.asarray(logits[:, 0]), np.asarray(full[:, t]),
                                   rtol=3e-2, atol=3e-2)


def test_flash_attention_matches_dense():
    """online_attention == dense softmax attention on random inputs."""
    B, S, KV, G, hd = 2, 128, 2, 3, 16
    q = jnp.asarray(rng.normal(size=(B, S, KV, G, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    for causal, window, cap in [(True, 0, 0.0), (True, 32, 0.0),
                                (False, 0, 0.0), (True, 0, 20.0)]:
        got = flash.online_attention(q, k, v, causal=causal, window=window,
                                     softcap=cap, chunk_q=32, chunk_k=32)
        # dense reference
        s = jnp.einsum("bqkgh,bskh->bkgqs", q, k) * hd ** -0.5
        if cap:
            s = cap * jnp.tanh(s / cap)
        pos = jnp.arange(S)
        m = jnp.ones((S, S), bool)
        if causal:
            m &= pos[None, :] <= pos[:, None]
        if window:
            m &= pos[None, :] > pos[:, None] - window
        s = jnp.where(m[None, None, None], s, -1e30)
        w = jax.nn.softmax(s, -1)
        want = jnp.einsum("bkgqs,bskh->bqkgh", w, v)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)


def test_flash_path_equals_dense_path_in_model(monkeypatch):
    """Force the chunked route in a real model and compare logits."""
    cfg = get_smoke("qwen3_8b")
    lm = LM(cfg)
    p = lm.init(jax.random.key(2))
    batch = _batch(cfg, B=1, S=64)
    dense = lm.forward(p, batch)
    monkeypatch.setattr(flash, "DENSE_LIMIT", 1)   # everything chunks
    chunked = lm.forward(p, batch)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(chunked),
                               rtol=2e-2, atol=2e-2)


def test_param_count_magnitudes():
    """Full configs land near their nameplate sizes (sanity on configs)."""
    expect = {
        "qwen3_8b": (7e9, 10e9),
        "starcoder2_3b": (2.5e9, 4e9),
        "nemotron_4_15b": (12e9, 18e9),
        "deepseek_v2_236b": (180e9, 280e9),
        "gemma3_4b": (3e9, 6e9),
        "hubert_xlarge": (0.8e9, 1.3e9),
        "xlstm_350m": (0.2e9, 0.6e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo < n < hi, (arch, n)


def test_moe_routing_is_sparse_and_weighted():
    cfg = get_smoke("granite_moe_1b_a400m")
    lm = LM(cfg)
    p = lm.init(jax.random.key(3))
    b = _batch(cfg)
    out = lm.forward(p, b)
    assert not bool(jnp.isnan(out).any())
    # capacity dropping at factor ~0: output must change
    import repro.models.moe as moe_mod
    cfg2 = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=0.01))
    lm2 = LM(cfg2)
    out2 = lm2.forward(p, b)
    assert float(jnp.abs(out - out2).max()) > 0
