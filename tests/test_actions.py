"""Shard-build action protocol + process-pool shard executor (DESIGN §17).

The contract: a ``shard_executor="process"`` plan produces *exactly* the
monolithic / thread-sharded answer — same join_size, same desummarized row
multiset, same aggregate values — while the shard pipelines run in real
spawned worker processes; worker spans stitch under ``phase:summarize``
(the PR 6 ``--expect-shards`` trace validation passes unchanged) and
worker metrics merge into the coordinator registry.  Fault posture: a
killed worker, a raised action, or a timed-out action degrades that shard
to the inline thread path — never kills the query, never double-counts.
"""

import os
import time

import numpy as np
import pytest

from test_plan import _random_instance, _row_multiset

from repro.core.api import GraphicalJoin
from repro.dist.actions import (ProcessShardExecutor, ShardBuildAction,
                                decode_action, decode_result, encode_action,
                                encode_result, perform_action,
                                shared_shard_executor,
                                shutdown_shared_executor)
from repro.obs.check import validate
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Tracer
from repro.plan.executor import Executor
from repro.plan.search import plan_query
from repro.relational.encoding import encode_query
from repro.relational.synth import figure1
from repro.relational.table import Catalog, Table


@pytest.fixture(scope="module", autouse=True)
def _drain_shared_pool():
    """Each module run starts and ends without a lingering spawn pool."""
    shutdown_shared_executor()
    yield
    shutdown_shared_executor()


def _figure1_action(shard=0, **kw):
    cat, q = figure1()
    enc = encode_query(cat, q)
    _, plan = plan_query(enc)
    return ShardBuildAction(shard=shard, enc=enc, order=tuple(plan.order),
                            step_estimates={s.var: s.product_entries
                                            for s in plan.steps}, **kw)


# ---------------------------------------------------------------------------
# Wire format.
# ---------------------------------------------------------------------------

def test_action_roundtrip_bytes():
    act = _figure1_action(shard=3, fault=None)
    act2 = decode_action(encode_action(act))
    assert act2.shard == 3
    assert act2.order == act.order
    assert act2.early_projection == act.early_projection
    assert act2.backend == "numpy"
    assert act2.step_estimates == pytest.approx(act.step_estimates)
    assert act2.enc.query == act.enc.query
    for a, b in zip(act.enc.encoded_tables, act2.enc.encoded_tables):
        assert sorted(a) == sorted(b)
        for v in a:
            np.testing.assert_array_equal(a[v], b[v])


def test_result_roundtrip_bytes():
    res = perform_action(_figure1_action())
    res2 = decode_result(encode_result(res))
    assert res2.shard == res.shard
    assert res2.join_size == res.join_size
    assert res2.gfjs.join_size == res.gfjs.join_size
    assert res2.step_products == pytest.approx(res.step_products)
    assert res2.step_seconds == pytest.approx(res.step_seconds)
    assert [s["name"] for s in res2.spans] == [s["name"] for s in res.spans]
    # worker spans nest under the shard root in the record set itself
    root = res2.spans[-1]
    assert root["name"] == "shard:0"
    assert any(s["parent_id"] == root["span_id"] for s in res2.spans[:-1])


def test_bad_container_rejected():
    act = _figure1_action()
    with pytest.raises(ValueError):
        decode_action(b"NOPE" + b"\0" * 32)
    with pytest.raises(ValueError):
        # a result container is not an action container
        decode_action(encode_result(perform_action(act)))


# ---------------------------------------------------------------------------
# Across a real spawned process.
# ---------------------------------------------------------------------------

def test_roundtrip_across_spawned_process():
    act = _figure1_action()
    want = perform_action(act)
    ex = ProcessShardExecutor(1)
    try:
        outs = ex.run([act])
    finally:
        ex.shutdown()
    assert len(outs) == 1
    got = outs[0].result
    assert not outs[0].retried, outs[0].error
    assert got.join_size == want.join_size
    assert got.step_products == pytest.approx(want.step_products)
    # a real worker shipped its metrics snapshot and span records
    assert got.metrics, "worker metrics snapshot missing"
    assert "gfjs.runs_per_level" in got.metrics
    assert [s["name"] for s in got.spans][-1] == "shard:0"


@pytest.mark.parametrize("shape,seed", [
    ("chain3", 3), ("star3", 5), ("triangle", 11), ("cycle4", 2),
])
def test_process_thread_mono_exact_equality(shape, seed):
    cat, query = _random_instance(shape, seed)
    all_vars = sorted({v for t in query.tables for _, v in t.var_map})
    mono = GraphicalJoin(cat, query)
    g_mono = mono.run()
    thr = GraphicalJoin(cat, query, partitions=2)
    g_thr = thr.run()
    prc = GraphicalJoin(cat, query, partitions=2, shard_executor="process")
    g_prc = prc.run()
    assert g_thr.join_size == g_mono.join_size
    assert g_prc.join_size == g_mono.join_size
    m0 = _row_multiset(mono, g_mono, all_vars)
    np.testing.assert_array_equal(m0, _row_multiset(thr, g_thr, all_vars))
    np.testing.assert_array_equal(m0, _row_multiset(prc, g_prc, all_vars))


def test_jax_backend_keeps_threads():
    """The process knob must not re-spawn an XLA runtime per shard."""
    cat, q = figure1()
    gj = GraphicalJoin(cat, q, partitions=2, shard_executor="process",
                       generation_backend="numpy")
    gj.run()
    assert gj._executor.shard_report["executor"] == "process"
    gj2 = GraphicalJoin(cat, q, partitions=2, shard_executor="process",
                        generation_backend="jax")
    gj2.run()
    assert gj2._executor.shard_report["executor"] == "thread"


# ---------------------------------------------------------------------------
# Observability: span stitching + metrics merge.
# ---------------------------------------------------------------------------

def test_process_spans_stitch_under_summarize():
    cat, q = figure1()
    tracer = Tracer()
    gj = GraphicalJoin(cat, q, partitions=2, shard_executor="process",
                       tracer=tracer)
    gj.run()
    doc = tracer.to_chrome_trace()
    errs = validate(doc, expect_shards=True)
    assert errs == [], errs
    shard_spans = tracer.find("shard")
    assert len(shard_spans) == 2
    summarize = [s for s in tracer.spans if s.name == "phase:summarize"]
    assert len(summarize) == 1
    for sp in shard_spans:
        assert sp.parent_id == summarize[0].span_id
        # rebased: the worker clock landed inside the coordinator window
        assert summarize[0].t0 <= sp.t1 <= summarize[0].t1 + 1e-6
        # child spans (eliminate/gfjs levels) re-homed under the shard root
        kids = [s for s in tracer.spans if s.parent_id == sp.span_id]
        assert any(s.name.startswith("eliminate:") for s in kids)


def test_process_metrics_merge_into_coordinator():
    cat, q = figure1()
    reg = MetricsRegistry()
    gj = GraphicalJoin(cat, q, partitions=2, shard_executor="process",
                       metrics=reg)
    gj.run()
    snap = reg.snapshot()
    # worker-side histograms crossed the process boundary and merged
    assert "gfjs.runs_per_level" in snap
    assert snap["gfjs.runs_per_level"]["count"] > 0
    assert snap["dist.shard_skew"]["type"] == "gauge"


def test_shard_report_shape_matches_thread_path():
    cat, q = figure1()
    gj_t = GraphicalJoin(cat, q, partitions=2)
    gj_t.run()
    gj_p = GraphicalJoin(cat, q, partitions=2, shard_executor="process")
    gj_p.run()
    rt, rp = gj_t._executor.shard_report, gj_p._executor.shard_report
    assert set(rt) == set(rp)
    assert rt["sizes"] == rp["sizes"]
    assert len(rt["seconds"]) == len(rp["seconds"])
    assert [sorted(m) for m in rt["step_seconds"]] == \
        [sorted(m) for m in rp["step_seconds"]]
    assert rp["executor"] == "process" and rt["executor"] == "thread"


# ---------------------------------------------------------------------------
# Fault injection: degrade, don't kill.
# ---------------------------------------------------------------------------

def test_worker_killed_mid_build_degrades_to_thread():
    act0 = _figure1_action(shard=0)
    act1 = _figure1_action(shard=1, fault="kill:1")
    want = perform_action(act0)
    ex = ProcessShardExecutor(1)
    try:
        outs = ex.run([act0, act1])
    finally:
        ex.shutdown()
    assert len(outs) == 2
    by_shard = {o.result.shard: o for o in outs}
    assert by_shard[1].retried and by_shard[1].error
    # the degraded shard still produced the right answer
    assert by_shard[1].result.join_size == want.join_size
    assert by_shard[0].result.join_size == want.join_size


def test_action_timeout_degrades_to_thread():
    act0 = _figure1_action(shard=0, fault="hang:0:60")
    act1 = _figure1_action(shard=1)
    ex = ProcessShardExecutor(2, timeout=3.0)
    t0 = time.perf_counter()
    try:
        outs = ex.run([act0, act1])
    finally:
        ex.shutdown()
    assert time.perf_counter() - t0 < 30.0   # never waits out the hang
    by_shard = {o.result.shard: o for o in outs}
    assert by_shard[0].retried
    assert by_shard[0].result.join_size == by_shard[1].result.join_size


def test_fault_hooks_never_fire_inline():
    """The inline (coordinator-thread) retry must ignore fault specs —
    an os._exit there would take the whole query down."""
    act = _figure1_action(shard=0, fault="kill:0")
    res = perform_action(act)    # not in a worker: fault is a no-op
    assert res.join_size >= 0
    os.environ["REPRO_SHARD_FAULT"] = "kill:0"
    try:
        res = perform_action(act)
        assert res.join_size >= 0
    finally:
        del os.environ["REPRO_SHARD_FAULT"]


def test_degraded_query_still_exact():
    """End-to-end: a killed shard worker degrades, the query answer is
    still exactly the monolithic answer and the report says degraded."""
    cat, query = _random_instance("triangle", 11)
    all_vars = sorted({v for t in query.tables for _, v in t.var_map})
    mono = GraphicalJoin(cat, query)
    m0 = _row_multiset(mono, mono.run(), all_vars)
    shutdown_shared_executor()
    os.environ["REPRO_SHARD_FAULT"] = "kill:1"
    try:
        prc = GraphicalJoin(cat, query, partitions=2,
                            shard_executor="process")
        g = prc.run()
        np.testing.assert_array_equal(m0, _row_multiset(prc, g, all_vars))
        assert prc._executor.shard_report["retries"] >= 1
    finally:
        del os.environ["REPRO_SHARD_FAULT"]
        shutdown_shared_executor()


# ---------------------------------------------------------------------------
# Pool lifecycle.
# ---------------------------------------------------------------------------

def test_shared_executor_persists_and_grows():
    a = shared_shard_executor(1)
    assert shared_shard_executor(1) is a          # reused
    b = shared_shard_executor(2)
    assert b is not a and b.max_workers == 2      # grown
    assert shared_shard_executor(1) is b          # never shrunk
    shutdown_shared_executor()


def test_dist_lazy_exports():
    import repro.dist as dist
    assert dist.ShardBuildAction is ShardBuildAction
    assert dist.ProcessShardExecutor is ProcessShardExecutor
    assert callable(dist.choose_partition_fold)
    assert callable(dist.fold_loads)


def test_plan_knob_validation():
    cat, q = figure1()
    enc = encode_query(cat, q)
    with pytest.raises(ValueError):
        plan_query(enc, shard_executor="process")          # partitions == 1
    with pytest.raises(ValueError):
        plan_query(enc, partitions=2, shard_executor="gpu")
    with pytest.raises(ValueError):
        plan_query(enc, partition_fold=2)                  # partitions == 1
    with pytest.raises(ValueError):
        plan_query(enc, partitions=2, partition_fold=0)
    _, plan = plan_query(enc, partitions=2, shard_executor="process",
                         partition_fold=2)
    assert plan.shard_executor == "process"
    assert plan.partition_fold == 2
    sig_thread = plan_query(enc, partitions=2)[1].signature()
    assert plan.signature() != sig_thread   # executor+fold are identity
