"""JAX engine vs numpy engine equivalence (the engines-agree property)."""

import numpy as np
import pytest

from repro.core.api import GraphicalJoin
from repro.core.engine_jax import (build_factor_jax, desummarize_jax,
                                   maybe_dense_message)
from repro.core.potentials import Factor
from repro.relational.synth import figure1, lastfm_like


def test_build_factor_jax_matches_numpy():
    rng = np.random.default_rng(0)
    cols = {"A": rng.integers(0, 40, 5000), "B": rng.integers(0, 60, 5000)}
    sizes = {"A": 40, "B": 60}
    a = build_factor_jax(cols, sizes, interpret=True)
    b = Factor.from_columns(cols, sizes)
    assert a.vars == b.vars
    np.testing.assert_array_equal(a.keys, b.keys)
    np.testing.assert_array_equal(a.bucket, b.bucket)


def test_desummarize_jax_matches_numpy():
    cat, query = figure1()
    gj = GraphicalJoin(cat, query)
    gfjs = gj.run()
    a = desummarize_jax(gfjs, interpret=True)
    b = gj.desummarize(gfjs, decode=False)
    for v in gfjs.column_order:
        np.testing.assert_array_equal(a[v], b[v])


def test_desummarize_jax_larger_query():
    cat, queries = lastfm_like(n_users=150, n_artists=120,
                               artists_per_user=5, friends_per_user=3)
    gj = GraphicalJoin(cat, queries["lastfm_A1"])
    gfjs = gj.run()
    a = desummarize_jax(gfjs, interpret=True)
    b = gj.desummarize(gfjs, decode=False)
    for v in gfjs.column_order:
        np.testing.assert_array_equal(a[v], b[v])


def test_dense_message_path_matches_coo():
    rng = np.random.default_rng(1)
    cols = {"P": rng.integers(0, 30, 2000), "V": rng.integers(0, 20, 2000)}
    sizes = {"P": 30, "V": 20}
    phi = Factor.from_columns(cols, sizes)
    msg = rng.integers(1, 50, 20).astype(np.int64)
    got = maybe_dense_message(phi, "V", msg, interpret=True)
    assert got is not None
    # reference: explicit per-parent contraction
    want = np.zeros(30, np.int64)
    for (p, v), c in zip(phi.keys, phi.bucket):
        want[p] += c * msg[v]
    np.testing.assert_array_equal(got, want)


def test_dense_message_declines_when_off_budget():
    keys = np.asarray([[0, 0]])
    phi = Factor(("P", "V"), keys, np.ones(1, np.int64), np.ones(1, np.int64),
                 (1 << 12, 1 << 12))
    assert maybe_dense_message(phi, "V", np.ones(1 << 12, np.int64),
                               interpret=True) is None
