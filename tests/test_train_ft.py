"""Training loop, checkpointing, and fault-tolerance tests.

The FT contract: a run that crashes mid-flight and resumes from its last
checkpoint produces BIT-IDENTICAL parameters to an uninterrupted run —
which requires atomic checkpoint commits, checkpointed data-iterator state,
and a deterministic train step.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, save_checkpoint, restore_checkpoint
from repro.checkpoint.store import available_steps
from repro.configs import get_smoke
from repro.data.pipeline import JoinCorpus, TokenBatcher
from repro.models.model import LM
from repro.relational.synth import lastfm_like
from repro.train.optim import AdamWConfig, init_state
from repro.train.train_step import TrainState, make_train_step
from repro.train.trainer import Trainer, TrainerConfig

# depth tier (DESIGN.md §13): deselect with -m "not slow"
pytestmark = pytest.mark.slow


def _tiny_setup(tmp_path, steps=8, crash_after=None, microbatches=1):
    cfg = get_smoke("qwen3_8b").scaled(num_layers=2, vocab=256)
    lm = LM(cfg)
    cat, queries = lastfm_like(n_users=60, n_artists=50, artists_per_user=4,
                               friends_per_user=3)
    corpus = JoinCorpus.build(cat, queries["lastfm_A1"], vocab=cfg.vocab)
    batcher = TokenBatcher(corpus, batch=4, seq=16)
    tcfg = TrainerConfig(steps=steps, checkpoint_every=4,
                         checkpoint_dir=str(tmp_path / "ckpt"),
                         log_every=4, crash_after_step=crash_after,
                         microbatches=microbatches)
    return Trainer(lm, AdamWConfig(warmup_steps=2, total_steps=steps),
                   batcher, tcfg), lm


def test_training_reduces_loss(tmp_path):
    trainer, lm = _tiny_setup(tmp_path, steps=30)
    trainer.run()
    losses = [m["loss"] for m in trainer.metrics_log]
    assert losses[-1] < losses[0], losses


def test_crash_and_resume_is_bit_exact(tmp_path):
    # uninterrupted reference run
    ref, _ = _tiny_setup(tmp_path / "ref", steps=8)
    ref_state = ref.run(seed=7)

    # crashed run: dies after step 6 (checkpoint at 4), then resumes
    crashed, _ = _tiny_setup(tmp_path / "crash", steps=8, crash_after=6)
    with pytest.raises(RuntimeError, match="injected failure"):
        crashed.run(seed=7)
    resumed, _ = _tiny_setup(tmp_path / "crash", steps=8)
    res_state = resumed.run(seed=7)

    for k in ref_state.params:
        np.testing.assert_array_equal(np.asarray(ref_state.params[k]),
                                      np.asarray(res_state.params[k]), err_msg=k)
    assert int(res_state.opt.step) == int(ref_state.opt.step) == 8


def test_microbatch_accumulation_matches_full_batch(tmp_path):
    cfg = get_smoke("qwen3_8b").scaled(num_layers=2, compute_dtype="float32",
                                       param_dtype="float32")
    lm = LM(cfg)
    p = lm.init(jax.random.key(0))
    state = TrainState(p, init_state(p))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)), jnp.int32)}
    ocfg = AdamWConfig(grad_clip=0.0)   # clip is batch-statistic dependent
    s1, m1 = jax.jit(make_train_step(lm, ocfg, microbatches=1))(state, batch)
    s2, m2 = jax.jit(make_train_step(lm, ocfg, microbatches=4))(state, batch)
    for k in s1.params:
        np.testing.assert_allclose(np.asarray(s1.params[k]),
                                   np.asarray(s2.params[k]),
                                   rtol=1e-5, atol=1e-6, err_msg=k)


def test_checkpoint_atomicity_and_integrity(tmp_path):
    tree = {"a": jnp.arange(10), "b": {"c": jnp.ones((3, 3))}}
    d = str(tmp_path / "c")
    save_checkpoint(d, 1, tree)
    save_checkpoint(d, 2, jax.tree.map(lambda x: x + 1, tree))
    assert available_steps(d) == [1, 2]
    back, step, _ = restore_checkpoint(d, tree)
    assert step == 2
    np.testing.assert_array_equal(np.asarray(back["a"]), np.arange(10) + 1)

    # corruption is detected
    import glob
    victim = glob.glob(os.path.join(d, "step_0000000002", "a.bin"))[0]
    with open(victim, "r+b") as f:
        f.seek(0)
        f.write(b"\xff\xff\xff\xff")
    with pytest.raises(IOError, match="corruption"):
        restore_checkpoint(d, tree)


def test_checkpoint_manager_retention_and_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "r"), keep=2)
    tree = {"w": jnp.zeros((4,))}
    for s in (1, 2, 3, 4):
        mgr.save_async(s, jax.tree.map(lambda x: x + s, tree))
    mgr.wait()
    assert available_steps(str(tmp_path / "r")) == [3, 4]


def test_batcher_state_roundtrip():
    cat, queries = lastfm_like(n_users=40, n_artists=30, artists_per_user=3,
                               friends_per_user=2)
    corpus = JoinCorpus.build(cat, queries["lastfm_A1"], vocab=128)
    b1 = TokenBatcher(corpus, batch=2, seq=8)
    _ = b1.next_batch()
    state = b1.state()
    want = b1.next_batch()
    b2 = TokenBatcher(corpus, batch=2, seq=8)
    b2.load_state(state)
    got = b2.next_batch()
    np.testing.assert_array_equal(want["tokens"], got["tokens"])


def test_host_sharded_batches_partition_the_corpus():
    cat, queries = lastfm_like(n_users=40, n_artists=30, artists_per_user=3,
                               friends_per_user=2)
    corpus = JoinCorpus.build(cat, queries["lastfm_A1"], vocab=128)
    n = corpus.num_rows
    ranges = [corpus.host_range(h, 4) for h in range(4)]
    assert ranges[0][0] == 0 and ranges[-1][1] == n
    for (a, b), (c, d) in zip(ranges, ranges[1:]):
        assert b == c
