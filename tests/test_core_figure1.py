"""Paper-faithfulness tests: the Figure 1/2 running example, exactly."""

import numpy as np
import pytest

from repro.core.api import GraphicalJoin
from repro.core.oracle import grouped_rle, oracle_join, sort_rows
from repro.relational.synth import figure1


def _gfjs_pairs(gfjs, var):
    for lvl in gfjs.levels:
        if var in lvl.vars:
            vals = gfjs.domains[var].decode(lvl.key_cols[var])
            return list(zip(vals.tolist(), lvl.freq.tolist()))
    raise KeyError(var)


def test_join_size_is_32():
    cat, query = figure1()
    gj = GraphicalJoin(cat, query)
    assert gj.join_size() == 32


def test_gfjs_matches_paper_figure2():
    """With the paper's elimination order O={D,C,B,A}, GFJS == Figure 2."""
    cat, query = figure1()
    gj = GraphicalJoin(cat, query, elimination_order=["D", "C", "B", "A"])
    gfjs = gj.run()
    assert gfjs.column_order == ["A", "B", "C", "D"]
    assert _gfjs_pairs(gfjs, "A") == [("a3", 32)]
    assert _gfjs_pairs(gfjs, "B") == [("b3", 8), ("b4", 24)]
    assert _gfjs_pairs(gfjs, "C") == [("c2", 8), ("c3", 16), ("c4", 8)]
    assert _gfjs_pairs(gfjs, "D") == [("d2", 8), ("d3", 16), ("d4", 8)]


def test_root_marginal_sums_to_join_size():
    cat, query = figure1()
    gj = GraphicalJoin(cat, query)
    gfjs = gj.run()
    for lvl in gfjs.levels:
        assert int(lvl.freq.sum()) == 32  # every level's runs cover |Q|


def test_desummarization_equals_sorted_oracle():
    cat, query = figure1()
    gj = GraphicalJoin(cat, query, elimination_order=["D", "C", "B", "A"])
    gfjs = gj.run()
    res = gj.desummarize(gfjs, decode=False)
    oc = oracle_join(gj.enc)
    o = sort_rows(oc, gfjs.column_order)
    g = np.stack([res[v] for v in gfjs.column_order], axis=1)
    assert np.array_equal(o, g)


def test_gfjs_is_grouped_rle_of_sorted_result():
    """Definition 1, verified literally against the materialized result."""
    cat, query = figure1()
    gj = GraphicalJoin(cat, query)
    gfjs = gj.run()
    oc = oracle_join(gj.enc)
    mat = sort_rows(oc, gfjs.column_order)
    groups = [len(l.vars) for l in gfjs.levels]
    rle = grouped_rle(mat, groups)
    for lvl, (vals, freqs) in zip(gfjs.levels, rle):
        got = np.stack([lvl.key_cols[v] for v in lvl.vars], axis=1)
        assert np.array_equal(got, vals)
        assert np.array_equal(lvl.freq, freqs)


def test_uir_never_generated():
    """b0/b1/c0 die in the 3-way join; GFJS must not contain them."""
    cat, query = figure1()
    gj = GraphicalJoin(cat, query)
    gfjs = gj.run()
    assert _gfjs_pairs(gfjs, "B") and all(v not in ("b0", "b1", "b2")
                                          for v, _ in _gfjs_pairs(gfjs, "B"))
    assert all(v != "c0" for v, _ in _gfjs_pairs(gfjs, "C"))


def test_bad_vs_good_elimination_order_cost():
    """Paper §3.3: eliminating an interior variable first forces a larger
    product (fill-in).  Both orders must still give identical results."""
    cat, query = figure1()
    good = GraphicalJoin(cat, query, elimination_order=["D", "C", "B", "A"]).run()
    bad = GraphicalJoin(cat, query, elimination_order=["B", "C", "D", "A"]).run()
    assert good.join_size == bad.join_size == 32
