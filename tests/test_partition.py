"""Hash-partitioned execution: ShardedGFJS vs the monolithic numpy oracle.

The contract (DESIGN.md §15): a plan with ``partitions=k`` produces a
:class:`ShardedGFJS` whose row count, desummarized row *multiset*, and
every SummaryFrame aggregate (including filtered group_by) exactly equal
the monolithic summary's — for every shard-shape edge case the hash can
produce: empty shards, all-rows-one-shard skew, more partitions than
distinct keys.  Device-parallel variants (forced virtual devices) live in
tests/test_dist.py; everything here is the host path.
"""

import itertools
import os
import tempfile

import numpy as np
import pytest

from test_plan import SHAPES, _random_instance, _row_multiset

from repro.core.api import GraphicalJoin
from repro.core.gfjs import ShardedGFJS, desummarize
from repro.core.storage import load_gfjs, save_gfjs
from repro.dist.partition import (PartitionScheme, choose_partition_var,
                                  hash_partition, parallel_desummarize,
                                  partition_counts, partition_encoded)
from repro.relational.encoding import encode_query
from repro.relational.query import JoinQuery
from repro.relational.synth import figure1, lastfm_like
from repro.relational.table import Catalog, Table
from repro.summary.algebra import ShardedSummaryFrame, SummaryFrame
from repro.summary.service import JoinService


def _assert_equal_summaries(gj_mono, g_mono, gj_part, g_part, variables):
    assert isinstance(g_part, ShardedGFJS)
    assert g_part.join_size == g_mono.join_size
    assert sum(g_part.shard_sizes()) == g_part.join_size
    assert list(g_part.column_order) == list(g_mono.column_order)
    all_vars = sorted(variables)
    assert np.array_equal(_row_multiset(gj_part, g_part, all_vars),
                          _row_multiset(gj_mono, g_mono, all_vars))


def _assert_equal_aggregates(g_mono, g_part, var, key):
    """Every frame aggregate, plus a filtered group_by, must match exactly."""
    f0, f1 = SummaryFrame.of(g_mono), SummaryFrame.of(g_part)
    assert isinstance(f1, ShardedSummaryFrame)
    assert f1.count() == f0.count()
    assert f1.sum(var) == f0.sum(var)
    assert f1.mean(var) == f0.mean(var)
    assert f1.min(var) == f0.min(var)
    assert f1.max(var) == f0.max(var)
    assert np.array_equal(f1.distinct(var), f0.distinct(var))
    assert f1.count_distinct(var) == f0.count_distinct(var)
    t0 = f0.group_by(key, n="count", s=("sum", var), avg=("mean", var),
                     lo=("min", var), hi=("max", var))
    t1 = f1.group_by(key, n="count", s=("sum", var), avg=("mean", var),
                     lo=("min", var), hi=("max", var))
    assert set(t0) == set(t1)
    for k in t0:
        assert np.array_equal(np.asarray(t0[k]), np.asarray(t1[k])), k
    # filtered: push a predicate through both frames, re-check
    dom = g_mono.domains[var].values
    if len(dom):
        pred = {var: lambda v: v <= dom[len(dom) // 2]}
        ff0, ff1 = f0.filter(pred), f1.filter(pred)
        assert ff1.count() == ff0.count()
        ft0 = ff0.group_by(key, n="count", s=("sum", var))
        ft1 = ff1.group_by(key, n="count", s=("sum", var))
        for k in ft0:
            assert np.array_equal(np.asarray(ft0[k]), np.asarray(ft1[k])), k


# ---------------------------------------------------------------------------
# partitioned == monolithic on test_plan's random acyclic + cyclic instances
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", ["chain3", "star3", "triangle", "cycle4"])
@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("partitions", [2, 4])
def test_partitioned_equals_monolithic_random(shape, seed, partitions):
    cat, query = _random_instance(shape, seed)
    mono = GraphicalJoin(cat, query)
    g0 = mono.run()
    part = GraphicalJoin(cat, query, partitions=partitions)
    g1 = part.run()
    assert part.plan().partitions == partitions
    _assert_equal_summaries(mono, g0, part, g1, query.variables)
    var = sorted(query.variables)[0]
    key = sorted(query.variables)[-1]
    _assert_equal_aggregates(g0, g1, var, key)


@pytest.mark.parametrize("seed", [3, 4])
def test_partitioned_projected_queries(seed):
    """Early projection: partitioning still exact, even when the partition
    variable itself is projected out of the result."""
    cat, query = _random_instance("chain3", seed, output=["A", "D"])
    mono = GraphicalJoin(cat, query)
    g0 = mono.run()
    for pvar in [None, "B", "C"]:          # B, C are projected out
        part = GraphicalJoin(cat, query, partitions=3, partition_var=pvar)
        g1 = part.run()
        if pvar is not None:
            assert part.plan().partition_var == pvar
        assert g1.join_size == g0.join_size
        assert np.array_equal(_row_multiset(part, g1, ["A", "D"]),
                              _row_multiset(mono, g0, ["A", "D"]))


# ---------------------------------------------------------------------------
# shard-merge edge cases
# ---------------------------------------------------------------------------

def _single_key_catalog():
    """Every row joins through one key value: all rows hash to ONE shard."""
    n = 40
    rng = np.random.default_rng(0)
    cat = Catalog.of(
        Table("l", {"k": np.zeros(n, np.int64),
                    "a": rng.integers(0, 5, n).astype(np.int64)}),
        Table("r", {"k": np.zeros(n, np.int64),
                    "b": rng.integers(0, 5, n).astype(np.int64)}),
    )
    q = JoinQuery.of("sk", [("l", {"k": "K", "a": "A"}),
                            ("r", {"k": "K", "b": "B"})])
    return cat, q


def test_all_rows_one_shard_skew():
    cat, q = _single_key_catalog()
    mono = GraphicalJoin(cat, q)
    g0 = mono.run()
    part = GraphicalJoin(cat, q, partitions=4, partition_var="K")
    g1 = part.run()
    sizes = g1.shard_sizes()
    assert sorted(sizes)[:-1] == [0, 0, 0]      # three empty shards
    assert max(sizes) == g0.join_size
    _assert_equal_summaries(mono, g0, part, g1, q.variables)
    _assert_equal_aggregates(g0, g1, "A", "B")


def test_partitions_exceed_distinct_keys():
    cat, query = _random_instance("chain3", 1)   # domains are 2..5 values
    mono = GraphicalJoin(cat, query)
    g0 = mono.run()
    part = GraphicalJoin(cat, query, partitions=8)
    g1 = part.run()
    assert g1.num_partitions == 8
    pvar = part.plan().partition_var
    assert sum(1 for s in g1.shard_sizes() if s == 0) >= \
        8 - g0.domains[pvar].size
    _assert_equal_summaries(mono, g0, part, g1, query.variables)


def test_empty_shard_frames_are_benign():
    """Aggregates over a frame with empty shards never raise or skew."""
    cat, q = _single_key_catalog()
    g1 = GraphicalJoin(cat, q, partitions=4, partition_var="K").run()
    f = SummaryFrame.of(g1)
    assert f.count() == g1.join_size
    empty = f.filter(A=lambda v: v < 0)          # kills every shard
    assert empty.count() == 0
    assert empty.min("A") is None and empty.max("A") is None
    assert len(empty.distinct("A")) == 0
    tab = empty.group_by("B", n="count", s=("sum", "A"), avg=("mean", "A"))
    assert all(len(np.asarray(v)) == 0 for v in tab.values())


def test_empty_join_partitioned():
    """Zero-row base tables: every shard is empty, everything still merges."""
    cat = Catalog.of(
        Table("l", {"k": np.zeros(0, np.int64), "a": np.zeros(0, np.int64)}),
        Table("r", {"k": np.zeros(0, np.int64), "b": np.zeros(0, np.int64)}))
    q = JoinQuery.of("e", [("l", {"k": "K", "a": "A"}),
                           ("r", {"k": "K", "b": "B"})])
    g = GraphicalJoin(cat, q, partitions=3).run()
    assert g.join_size == 0 and g.shard_sizes() == [0, 0, 0]
    assert SummaryFrame.of(g).count() == 0
    out = desummarize(g, decode=False)
    assert all(len(v) == 0 for v in out.values())


# ---------------------------------------------------------------------------
# partition layer unit behavior
# ---------------------------------------------------------------------------

def test_hash_partition_covers_and_is_deterministic():
    codes = np.arange(10_000, dtype=np.int64)
    for k in (2, 3, 7):
        p = hash_partition(codes, k)
        assert p.min() >= 0 and p.max() < k
        assert np.array_equal(p, hash_partition(codes, k))
        # rough balance on a dense code range (multiplicative hash)
        counts = np.bincount(p, minlength=k)
        assert counts.min() > len(codes) // (4 * k)
    assert not np.array_equal(hash_partition(codes, 4),
                              hash_partition(codes, 4, salt=1))
    with pytest.raises(ValueError):
        hash_partition(codes, 0)


def test_partition_encoded_replicates_by_reference():
    cat, q = figure1()
    enc = encode_query(cat, q)
    scheme = PartitionScheme("B", 3)
    shards = partition_encoded(enc, scheme)
    assert len(shards) == 3
    total = partition_counts(enc, scheme)
    assert int(total.sum()) == sum(
        len(c["B"]) for c in enc.encoded_tables if "B" in c)
    for s, enc_s in enumerate(shards):
        for occ, occ_s in zip(enc.encoded_tables, enc_s.encoded_tables):
            if "B" in occ:
                assert np.all(scheme.shard_of(occ_s["B"]) == s)
            else:
                assert occ_s is occ             # replication is by reference
    with pytest.raises(ValueError):
        partition_encoded(enc, PartitionScheme("nope", 2))


def test_choose_partition_var_picks_costliest_step():
    cat, q = figure1()
    enc = encode_query(cat, q)
    from repro.plan.search import plan_query
    logical, plan = plan_query(enc)
    pvar = choose_partition_var(plan.steps, plan.order)
    costliest = max(plan.steps, key=lambda s: s.product_entries)
    assert pvar == costliest.var
    # empty steps: falls back to the root
    assert choose_partition_var((), ("A", "B")) == "B"
    with pytest.raises(ValueError):
        choose_partition_var((), ())


def test_sharded_range_and_row_access():
    """desummarize_range / row_at resolve through the shard-concatenated
    row order (the same order desummarize emits)."""
    from repro.core.gfjs import desummarize_range, row_at
    cat, query = _random_instance("chain3", 6)
    gj = GraphicalJoin(cat, query, partitions=3)
    g = gj.run()
    if g.join_size == 0:
        pytest.skip("degenerate instance")
    full = desummarize(g, decode=False)
    n = g.join_size
    for lo, hi in [(0, n), (0, min(5, n)), (n // 3, 2 * n // 3),
                   (n - 1, n), (2, 2), (n, n + 9)]:
        part = desummarize_range(g, lo, hi, decode=False)
        for v in g.column_order:
            np.testing.assert_array_equal(
                part[v], full[v][max(lo, 0):min(hi, n)])
    for t in {0, n // 2, n - 1}:
        row = row_at(g, t, decode=False)
        assert all(row[v] == int(full[v][t]) for v in g.column_order)
    with pytest.raises(IndexError):
        row_at(g, n)


def test_partition_layer_imports_without_jax():
    """Planning a partitioned query must never force the jax import
    (repro.dist resolves its jax-dependent submodules lazily)."""
    import subprocess
    import sys as _sys
    code = (
        "import sys\n"
        "from repro.relational.synth import figure1\n"
        "from repro.relational.encoding import encode_query\n"
        "from repro.plan.search import plan_query\n"
        "from repro.core.api import GraphicalJoin\n"
        "cat, q = figure1()\n"
        "plan_query(encode_query(cat, q), partitions=4)\n"
        "GraphicalJoin(cat, q, partitions=4).run()\n"
        "assert 'jax' not in sys.modules, 'jax import leaked'\n"
        "print('ok')\n")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    out = subprocess.run([_sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0 and "ok" in out.stdout, out.stderr


def test_parallel_desummarize_matches_serial():
    cat, qs = lastfm_like(n_users=60, n_artists=50, artists_per_user=4,
                          friends_per_user=3)
    q = qs["lastfm_A1"]
    mono = GraphicalJoin(cat, q)
    g0 = mono.run()
    full = mono.desummarize(g0, decode=False)
    par = parallel_desummarize(g0, 5)            # range-sharded GFJS path
    for v in g0.column_order:
        np.testing.assert_array_equal(full[v], par[v])
    g1 = GraphicalJoin(cat, q, partitions=3).run()
    ref = desummarize(g1, decode=False)          # shard-concatenated order
    par2 = parallel_desummarize(g1, 3)
    for v in g1.column_order:
        np.testing.assert_array_equal(ref[v], par2[v])


# ---------------------------------------------------------------------------
# plan identity, explain, and the plan-feedback actuals
# ---------------------------------------------------------------------------

def test_partitions_flow_into_signature_and_explain():
    cat, q = figure1()
    p1 = GraphicalJoin(cat, q).plan()
    p2 = GraphicalJoin(cat, q, partitions=4).plan()
    p3 = GraphicalJoin(cat, q, partitions=2).plan()
    assert p1.partitions == 1 and p1.partition_var is None
    assert p2.partitions == 4 and p2.partition_var in q.variables
    assert len({p1.signature(), p2.signature(), p3.signature()}) == 3
    gj = GraphicalJoin(cat, q, partitions=4)
    gj.run()
    text = gj.explain()
    assert f"partitions        : 4 by hash({gj.plan().partition_var})" in text
    assert "x est)" in text                     # estimate-vs-actual drift
    with pytest.raises(ValueError):
        GraphicalJoin(cat, q, partitions=0).plan()
    with pytest.raises(ValueError):
        GraphicalJoin(cat, q, partitions=2, partition_var="Z").plan()
    # partition_var without partitions would be silently monolithic: refuse
    with pytest.raises(ValueError):
        GraphicalJoin(cat, q, partition_var="B").plan()
    # record_trace (incremental splicing) cannot follow shard structure:
    # refuse up front rather than erroring at capture_state much later
    with pytest.raises(ValueError):
        GraphicalJoin(cat, q, partitions=2, record_trace=True)
    with pytest.raises(ValueError):
        GraphicalJoin(cat, q, plan=GraphicalJoin(cat, q, partitions=2).plan(),
                      record_trace=True)


def test_partitioned_summary_is_memoized():
    """run()/join_size()/aggregate() after a partitioned build reuse the
    merged summary instead of paying the k-shard build again."""
    cat, q = figure1()
    gj = GraphicalJoin(cat, q, partitions=3)
    g1 = gj.run()
    assert gj.run() is g1                     # memoized, not rebuilt
    assert gj.join_size() == g1.join_size
    assert gj.aggregate("count", gfjs=g1) == g1.join_size
    gj.build_model()                          # re-entry clears the memo
    g2 = gj.run()
    assert g2 is not g1 and g2.join_size == g1.join_size


def test_step_actuals_partition_exactly():
    """Summed shard products == monolithic products: the hash split loses
    and duplicates nothing on partitioned steps (replicated steps excepted
    when the partition variable does not reach them)."""
    cat, query = _random_instance("chain3", 2)
    mono = GraphicalJoin(cat, query)
    mono.run()
    part = GraphicalJoin(cat, query, partitions=4)
    part.run()
    pvar = part.plan().partition_var
    mono_act = mono._executor.step_actuals
    part_act = part._executor.step_actuals
    assert set(mono_act) == set(part_act)
    # the partitioned step itself always splits exactly
    assert part_act[pvar] == mono_act[pvar]


def test_monolithic_signature_unchanged_by_partition_fields():
    """partitions=1 plans hash identically to pre-partitioning plans (the
    fields only enter the canon when > 1) — spilled caches stay valid."""
    cat, q = figure1()
    plan = GraphicalJoin(cat, q, elimination_order=["D", "C", "B", "A"]).plan()
    canon_wo = {
        "order": list(plan.order),
        "early_projection": bool(plan.early_projection),
        "backends": dict(sorted(plan.backends.items())),
        "materialize": plan.materialize,
    }
    import hashlib, json
    expect = hashlib.sha256(
        json.dumps(canon_wo, separators=(",", ":")).encode()).hexdigest()[:16]
    assert plan.signature() == expect


# ---------------------------------------------------------------------------
# storage + cache + service
# ---------------------------------------------------------------------------

def test_sharded_storage_roundtrip():
    cat, query = _random_instance("cycle4", 0)
    g = GraphicalJoin(cat, query, partitions=3).run()
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "s.gfjs")
        save_gfjs(g, path)
        back = load_gfjs(path)
    assert isinstance(back, ShardedGFJS)
    assert back.join_size == g.join_size
    assert back.partition_var == g.partition_var
    assert back.shard_sizes() == g.shard_sizes()
    a, b = desummarize(g, decode=False), desummarize(back, decode=False)
    for v in g.column_order:
        np.testing.assert_array_equal(a[v], b[v])


def test_service_partitioned_hits_and_spills_like_monolithic():
    cat, qs = lastfm_like(n_users=60, n_artists=50, artists_per_user=4,
                          friends_per_user=3)
    q1, q2 = qs["lastfm_A1"], qs["lastfm_tri"]
    with tempfile.TemporaryDirectory() as tmp:
        # budget of 1 byte: admitting the second summary evicts (and
        # spills) the first, so the next q1 request promotes from disk
        svc = JoinService(cat, partitions=3, spill_dir=tmp, byte_budget=1)
        r1 = svc.frame(q1)
        assert r1.source == "computed"
        assert isinstance(r1.frame.gfjs, ShardedGFJS)
        assert svc.frame(q1).source == "memory"
        svc.frame(q2)
        r3 = svc.frame(q1)
        assert r3.source == "disk"
        assert isinstance(r3.frame.gfjs, ShardedGFJS)
        assert r3.frame.count() == r1.frame.count()


def test_service_partitioned_append_falls_back_to_rebuild():
    """Appends on partitioned summaries rebuild (no splice-refresh) and
    the rebuilt answers track the live data exactly."""
    cat, qs = lastfm_like(n_users=50, n_artists=40, artists_per_user=3,
                          friends_per_user=2)
    q = qs["lastfm_A1"]
    svc = JoinService(cat, partitions=3)
    before = svc.count(q)
    name = sorted({qt.table for qt in q.tables})[0]
    rows = {c: cat[name][c][:5] for c in cat[name].columns}
    svc.append(name, rows)
    reply = svc.frame(q)
    assert reply.source == "computed"            # rebuilt, never "refreshed"
    assert svc.stats()["refreshed_requests"] == 0
    fresh = JoinService(cat, partitions=1)
    assert reply.frame.count() == fresh.count(q)
    assert svc.count(q) >= before                # appends only grow the join


def test_serve_provider_is_shape_oblivious():
    """RelationalFeatureProvider over a partitioned service == monolithic
    features, warm pulls are cache hits, appends keep it live (rebuild)."""
    from repro.serve.engine import RelationalFeatureProvider
    cat, qs = lastfm_like(n_users=50, n_artists=40, artists_per_user=4,
                          friends_per_user=3)
    q = qs["lastfm_A1"]
    svc_p = JoinService(cat, partitions=3)
    svc_m = JoinService(cat)
    keys = np.asarray([0, 1, 7, 10**9])
    aggs = {"n_rows": "count", "total": ("sum", "A1")}
    prov_p = RelationalFeatureProvider(svc_p, q, key_var="U1", aggs=aggs)
    prov_m = RelationalFeatureProvider(svc_m, q, key_var="U1", aggs=aggs)
    np.testing.assert_array_equal(prov_p.features(keys),
                                  prov_m.features(keys))
    before = svc_p.stats()["misses"]
    prov_p.refresh()
    prov_p.features(keys)
    assert svc_p.stats()["misses"] == before       # warm pull: cache hit
    name = sorted({qt.table for qt in q.tables})[0]
    svc_p.append(name, {c: cat[name][c][:4] for c in cat[name].columns})
    svc_m.append(name, {c: cat[name][c][:4] for c in cat[name].columns})
    np.testing.assert_array_equal(prov_p.features(keys),
                                  prov_m.features(keys))


def test_sharded_frame_to_gfjs_roundtrip():
    cat, query = _random_instance("triangle", 5)
    mono = GraphicalJoin(cat, query)
    g0 = mono.run()
    part = GraphicalJoin(cat, query, partitions=4)
    g1 = part.run()
    var = sorted(query.variables)[0]
    dom = g0.domains[var].values
    if len(dom) == 0:
        pytest.skip("empty instance")
    pred = {var: lambda v: v != dom[0]}
    filt0 = SummaryFrame.of(g0).filter(pred).to_gfjs()
    filt1 = SummaryFrame.of(g1).filter(pred).to_gfjs()
    assert isinstance(filt1, ShardedGFJS)
    assert filt1.join_size == filt0.join_size
    all_vars = sorted(query.variables)
    assert np.array_equal(_row_multiset(mono, filt0, all_vars),
                          _row_multiset(part, filt1, all_vars))


# ---------------------------------------------------------------------------
# Skew-aware partitioning (PR 7): top-key discount + over-partition/fold.
# ---------------------------------------------------------------------------

def test_fold_loads_lpt_balancing():
    from repro.dist.partition import fold_loads
    # fold=1 degenerates: one shard per worker, loads pass through
    np.testing.assert_allclose(sorted(fold_loads([3, 1, 2], 3)), [1, 2, 3])
    # greedy largest-first: 5->w0, 4->w1, 3->w1, 3->w0, 3->w1
    loads = fold_loads([5, 4, 3, 3, 3], 2)
    assert sorted(loads) == [8, 10]
    # more workers than shards: empties allowed
    loads = fold_loads([7], 3)
    assert sorted(loads) == [0, 0, 7]


def test_choose_partition_var_discounts_hot_keys():
    """A big step on a one-hot-key variable loses to a slightly smaller
    step whose key actually splits."""
    from dataclasses import dataclass as _dc

    @_dc
    class _Step:
        var: str
        product_entries: float

    from repro.plan.stats import FactorStats, QueryStats
    hot = np.zeros(16); hot[0] = 1000.0           # all mass on one code
    flat = np.full(16, 10.0)                      # perfectly spread
    stats = QueryStats(
        sizes={"H": 16, "F": 16},
        factors=[],
        factor_stats=[
            FactorStats(("H",), 1000.0, {"H": 1.0}, {"H": hot}),
            FactorStats(("F",), 160.0, {"F": 16.0}, {"F": flat}),
        ])
    steps = [_Step("H", 1000.0), _Step("F", 900.0)]
    # without stats: raw product wins
    assert choose_partition_var(steps, ("H", "F")) == "H"
    # with stats at k=4: H's shardable benefit is 0, F wins
    from repro.dist.partition import choose_partition_var as cpv
    assert cpv(steps, ("H", "F"), stats=stats, partitions=4) == "F"
    # balanced candidates degenerate to the raw-product rule
    stats_flat = QueryStats(
        sizes={"H": 16, "F": 16}, factors=[],
        factor_stats=[
            FactorStats(("H",), 160.0, {"H": 16.0}, {"H": flat.copy()}),
            FactorStats(("F",), 160.0, {"F": 16.0}, {"F": flat.copy()}),
        ])
    assert cpv(steps, ("H", "F"), stats=stats_flat, partitions=4) == "H"


def test_choose_partition_fold_balanced_stays_one():
    from repro.dist.partition import choose_partition_fold
    from repro.plan.stats import FactorStats, QueryStats
    flat = np.full(1024, 5.0)
    stats = QueryStats(
        sizes={"V": 1024}, factors=[],
        factor_stats=[FactorStats(("V",), 5120.0, {"V": 1024.0},
                                  {"V": flat})])
    assert choose_partition_fold(stats, "V", 1) == 1        # monolithic
    assert choose_partition_fold(None, "V", 4) == 1         # no stats
    assert choose_partition_fold(stats, "V", 4) == 1        # balanced
    # no degree vector for the var: unknowable, stay at 1
    assert choose_partition_fold(stats, "W", 4) == 1


def test_choose_partition_fold_smooths_zipf():
    """A Zipf-ish degree vector at k=4: over-partitioning must be chosen
    and must *predict* better folded balance than fold=1."""
    from repro.dist.partition import (choose_partition_fold, fold_loads,
                                      hash_partition)
    from repro.plan.stats import FactorStats, QueryStats
    rng = np.random.default_rng(0)
    deg = (1.0 / np.arange(1, 2049) ** 1.1) * 1e4
    rng.shuffle(deg)
    stats = QueryStats(
        sizes={"V": len(deg)}, factors=[],
        factor_stats=[FactorStats(("V",), float(deg.sum()),
                                  {"V": float(len(deg))}, {"V": deg})])
    k = 4
    f = choose_partition_fold(stats, "V", k)
    codes = np.arange(len(deg))

    def worker_skew(fold):
        pids = hash_partition(codes, k * fold)
        loads = np.bincount(pids, weights=deg, minlength=k * fold)
        w = fold_loads(loads, k)
        return float(w.max() / w.mean())

    assert f > 1
    assert worker_skew(f) <= worker_skew(1) + 1e-9


@pytest.mark.parametrize("shape,seed,fold", [
    ("chain3", 3, 2), ("triangle", 11, 4), ("cycle4", 2, 2),
])
def test_folded_partitions_equal_monolithic(shape, seed, fold):
    """k workers x f virtual shards is still exactly the monolithic
    answer (the fold only changes shard count, never membership)."""
    cat, query = _random_instance(shape, seed)
    all_vars = sorted({v for t in query.tables for _, v in t.var_map})
    mono = GraphicalJoin(cat, query)
    m0 = _row_multiset(mono, mono.run(), all_vars)
    gj = GraphicalJoin(cat, query, partitions=2, partition_fold=fold)
    sharded = gj.run()
    assert sharded.num_partitions == 2 * fold
    np.testing.assert_array_equal(
        m0, _row_multiset(gj, sharded, all_vars))
    rep = gj._executor.shard_report
    assert len(rep["sizes"]) == 2 * fold
    assert rep["workers"] == 2


def test_fold_reports_worker_skew_not_shard_skew():
    """With fold > 1 the reported skew is over folded per-worker loads —
    it can only improve on (never exceed) the raw virtual-shard skew."""
    from repro.dist.partition import fold_loads
    cat, qs = lastfm_like(n_users=60, n_artists=40, artists_per_user=4,
                          friends_per_user=3)
    q = qs["lastfm_tri"]
    gj = GraphicalJoin(cat, q, partitions=2, partition_fold=4)
    gj.run()
    rep = gj._executor.shard_report
    sizes = rep["sizes"]
    w = fold_loads(sizes, 2)
    raw_mean = sum(sizes) / len(sizes)
    raw_skew = max(sizes) / raw_mean if raw_mean > 0 else 1.0
    assert rep["skew"] == pytest.approx(float(w.max() / w.mean()))
    # folded worker skew is bounded by the raw per-shard skew
    assert rep["skew"] <= raw_skew + 1e-9


def test_explain_renders_fold_and_executor():
    cat, q = figure1()
    gj = GraphicalJoin(cat, q, partitions=4, partition_fold=2,
                       shard_executor="process")
    plan = gj.plan()
    text = plan.explain()
    pvar = plan.partition_var
    # the PR 5 substring is untouched (append-only changes to that line)
    assert f"partitions        : 4 by hash({pvar})" in text
    assert "x2 fold (8 virtual)" in text
    assert "executor=process" in text
