"""Differential harness for incremental summary maintenance.

The contract under test: for any query and any sequence of base-table
appends, the incrementally refreshed GFJS is *equal* to a from-scratch
rebuild under the same physical plan — level for level, run for run — and
therefore equivalent on desummarized rows and on every summary-algebra
aggregate.  Randomization uses seeded numpy RNG (hypothesis-optional,
like the other property suites): random acyclic and cyclic query shapes,
random data, random append sequences that deliberately mix existing and
never-seen values (the latter force dictionary-domain growth and code
remaps).

Also covered: the plan-level dirty-step map, delta chaining/staleness,
`Factor.merge_counts`, the service append/refresh loop, cache
upgrade-in-place, and the serve-path feature provider surviving growth.
"""

import collections

import numpy as np
import pytest

from repro.core.api import GraphicalJoin
from repro.core.gfjs import desummarize
from repro.core.potentials import Factor
from repro.relational.query import JoinQuery
from repro.relational.synth import figure1, lastfm_like
from repro.relational.table import Catalog, Table
from repro.summary.algebra import SummaryFrame
from repro.summary.incremental import (StaleDeltaError, capture_state,
                                       refresh_state)
from repro.summary.service import JoinService

SHAPES = {
    "chain3": [("t0", {"x0": "A", "x1": "B"}), ("t1", {"x0": "B", "x1": "C"}),
               ("t2", {"x0": "C", "x1": "D"})],
    "star3": [("t0", {"x0": "M", "x1": "A"}), ("t1", {"x0": "M", "x1": "B"}),
              ("t2", {"x0": "M", "x1": "C"})],
    "selfjoin": [("t0", {"x0": "A", "x1": "B"}), ("t0", {"x0": "B", "x1": "C"})],
    "triangle": [("t0", {"x0": "A", "x1": "B"}), ("t1", {"x0": "B", "x1": "C"}),
                 ("t2", {"x0": "C", "x1": "A"})],
    "cycle4": [("t0", {"x0": "A", "x1": "B"}), ("t1", {"x0": "B", "x1": "C"}),
               ("t2", {"x0": "C", "x1": "D"}), ("t3", {"x0": "D", "x1": "A"})],
}


def random_instance(shape: str, seed: int):
    spec = SHAPES[shape]
    rng = np.random.default_rng(seed)
    domain = int(rng.integers(2, 6))
    cat = Catalog()
    for tname, vm in spec:
        if tname in cat:
            continue
        nrows = int(rng.integers(1, 20))
        cols = {c: rng.integers(0, domain, nrows).astype(np.int64)
                for c in vm.keys()}
        cat.add(Table(tname, cols))
    return cat, JoinQuery.of(shape, spec), domain, rng


def assert_gfjs_equal(a, b):
    """Strict structural equality: same levels, runs, codes, frequencies."""
    assert a.join_size == b.join_size
    assert a.column_order == b.column_order
    assert len(a.levels) == len(b.levels)
    for la, lb in zip(a.levels, b.levels):
        assert la.vars == lb.vars
        assert np.array_equal(la.freq, lb.freq)
        for v in la.vars:
            assert np.array_equal(la.key_cols[v], lb.key_cols[v])


def assert_aggregates_match(gfjs, raw):
    """Every summary-algebra aggregate equals brute force over ``raw``."""
    frame = SummaryFrame.of(gfjs)
    some = gfjs.column_order[0]
    n = len(raw[some])
    assert frame.count() == n
    for v in gfjs.column_order:
        if n == 0:
            assert frame.sum(v) == 0
            assert frame.min(v) is None and frame.max(v) is None
            assert frame.count_distinct(v) == 0
        else:
            assert frame.sum(v) == int(raw[v].sum())
            assert frame.mean(v) == pytest.approx(raw[v].mean())
            assert frame.min(v) == raw[v].min()
            assert frame.max(v) == raw[v].max()
            assert frame.count_distinct(v) == len(np.unique(raw[v]))
    if n:
        key, val = gfjs.column_order[0], gfjs.column_order[-1]
        got = frame.group_by(key, n="count", total=("sum", val))
        cnt = collections.Counter(raw[key])
        sums = collections.defaultdict(int)
        for k, x in zip(raw[key], raw[val]):
            sums[k] += x
        ks = sorted(cnt)
        assert list(got[key]) == ks
        assert [int(x) for x in got["n"]] == [cnt[k] for k in ks]
        assert [int(x) for x in got["total"]] == [sums[k] for k in ks]


def random_block(rng, table: Table, domain: int):
    """0-6 random rows; values range past the domain to force growth."""
    n = int(rng.integers(0, 7))
    return {c: rng.integers(0, domain + 2, n).astype(np.int64)
            for c in table.column_names}


# ---------------------------------------------------------------------------
# the differential harness (acceptance: >= 20 random append sequences on
# acyclic and cyclic queries; here 5 shapes x 5 seeds = 25, 4 appends each)
# ---------------------------------------------------------------------------

CASES = [(s, seed) for s in SHAPES for seed in range(5)]


@pytest.mark.parametrize("shape,seed", CASES)
def test_refresh_equals_rebuild_differentially(shape, seed):
    cat, query, domain, rng = random_instance(shape, seed)
    gj = GraphicalJoin(cat, query, record_trace=True)
    state = gj.capture_state(gj.run())

    tables = list(cat.names())
    for step in range(4):
        tname = tables[int(rng.integers(0, len(tables)))]
        delta = cat.append(tname, random_block(rng, cat[tname], domain))
        state = gj.refresh(state, delta)

        rebuilt = GraphicalJoin(cat, query, plan=state.plan).run()
        assert_gfjs_equal(state.gfjs, rebuilt)

        raw = desummarize(rebuilt)
        got = desummarize(state.gfjs)
        for v in rebuilt.column_order:
            assert np.array_equal(got[v], raw[v])
        assert_aggregates_match(state.gfjs, raw)


@pytest.mark.parametrize("shape,seed", [("chain3", 11), ("triangle", 12)])
def test_refresh_with_batched_deltas(shape, seed):
    """Several queued deltas (mixed tables) applied in one refresh."""
    cat, query, domain, rng = random_instance(shape, seed)
    gj = GraphicalJoin(cat, query, record_trace=True)
    state = gj.capture_state(gj.run())
    deltas = []
    for tname in cat.names():
        for _ in range(2):
            deltas.append(cat.append(
                tname, random_block(rng, cat[tname], domain)))
    state = gj.refresh(state, deltas)
    rebuilt = GraphicalJoin(cat, query, plan=state.plan).run()
    assert_gfjs_equal(state.gfjs, rebuilt)


def test_refresh_from_empty_table():
    """A summary built over an empty table grows into a live one."""
    cat = Catalog.of(
        Table("t0", {"x0": np.zeros(0, np.int64), "x1": np.zeros(0, np.int64)}),
        Table("t1", {"x0": np.asarray([0, 1, 2]), "x1": np.asarray([5, 6, 7])}),
    )
    query = JoinQuery.of("grow", [("t0", {"x0": "A", "x1": "B"}),
                                  ("t1", {"x0": "B", "x1": "C"})])
    gj = GraphicalJoin(cat, query, record_trace=True)
    gfjs = gj.run()
    assert gfjs.join_size == 0
    state = gj.capture_state(gfjs)
    delta = cat.append("t0", {"x0": [9, 9], "x1": [0, 1]})
    state = gj.refresh(state, delta)
    rebuilt = GraphicalJoin(cat, query, plan=state.plan).run()
    assert state.gfjs.join_size == 2
    assert_gfjs_equal(state.gfjs, rebuilt)


def test_zero_row_append_is_a_version_noop():
    cat, query = figure1()
    gj = GraphicalJoin(cat, query, record_trace=True)
    state = gj.capture_state(gj.run())
    delta = cat["table1"].append({"A": [], "B": []})
    assert delta.base_version == delta.new_version
    state2, report = refresh_state(state, [delta])
    assert report["dirty_steps"] == 0
    assert_gfjs_equal(state2.gfjs, state.gfjs)


def test_stale_delta_chain_raises():
    cat, query = figure1()
    gj = GraphicalJoin(cat, query, record_trace=True)
    state = gj.capture_state(gj.run())
    d1 = cat.append("table1", {"A": ["a0"], "B": ["b0"]})
    d2 = cat.append("table1", {"A": ["a1"], "B": ["b1"]})
    with pytest.raises(StaleDeltaError):
        refresh_state(state, [d2])          # skipped d1: chain broken
    state = gj.refresh(state, [d1, d2])     # in order: fine
    rebuilt = GraphicalJoin(cat, query, plan=state.plan).run()
    assert_gfjs_equal(state.gfjs, rebuilt)


def test_mixed_dtype_append_rejected():
    cat, query = figure1()
    with pytest.raises(TypeError):
        cat["table1"].append({"A": [1], "B": [2]})   # strings table


def test_merge_counts_is_group_by_of_the_union():
    rng = np.random.default_rng(3)
    sizes = {"A": 5, "B": 4}
    a = {"A": rng.integers(0, 5, 30), "B": rng.integers(0, 4, 30)}
    b = {"A": rng.integers(0, 5, 11), "B": rng.integers(0, 4, 11)}
    merged = Factor.from_columns(a, sizes).merge_counts(
        Factor.from_columns(b, sizes))
    both = {k: np.concatenate([a[k], b[k]]) for k in a}
    want = Factor.from_columns(both, sizes)
    assert np.array_equal(merged.keys, want.keys)
    assert np.array_equal(merged.bucket, want.bucket)
    assert np.array_equal(merged.fac, want.fac)


# ---------------------------------------------------------------------------
# plan-level dirty-step map
# ---------------------------------------------------------------------------

def test_plan_dirty_steps_match_refresher():
    cat, query = figure1()
    gj = GraphicalJoin(cat, query, record_trace=True)
    state = gj.capture_state(gj.run())
    plan = state.plan
    # every step is tagged with the base tables feeding it, transitively
    assert all(s.tables for s in plan.steps)
    for tname in cat.names():
        dirty = plan.dirty_steps(tname)
        assert set(dirty) <= set(plan.order[:-1])
        frac = plan.refresh_fraction(tname)
        assert 0.0 <= frac <= 1.0
        # the refresher re-runs exactly the plan's dirty set
        delta = cat.append(tname, {c: cat[tname][c][:1]
                                   for c in cat[tname].column_names})
        state, report = refresh_state(state, [delta])
        assert report["dirty_steps"] == len(dirty)


def test_explain_renders_step_tables():
    cat, query = figure1()
    gj = GraphicalJoin(cat, query)
    gj.run()
    assert "tables=(" in gj.explain()


# ---------------------------------------------------------------------------
# service + cache + serve wiring
# ---------------------------------------------------------------------------

def test_service_append_refreshes_lazily():
    cat, qs = lastfm_like(n_users=40, n_artists=30, artists_per_user=4,
                          friends_per_user=3)
    svc = JoinService(cat)
    q = qs["lastfm_A1"]
    assert svc.frame(q).source == "computed"
    rng = np.random.default_rng(7)
    svc.append("user_friends", {"userID": rng.integers(0, 40, 5),
                                "friendID": rng.integers(0, 40, 5)})
    reply = svc.frame(q)
    assert reply.source == "refreshed"
    assert "refresh" in reply.timings
    # the refreshed entry is a first-class cache resident
    assert svc.frame(q).source == "memory"
    # equivalence against an independent cold compute on the grown catalog
    cold = JoinService(cat, incremental=False)
    assert reply.frame.count() == cold.count(q)
    st = svc.stats()
    assert st["refreshed_requests"] == 1 and st["refreshes"] == 1


def test_service_refresh_differential_with_growth():
    """Service-level differential: appends with brand-new keys each round."""
    cat, qs = lastfm_like(n_users=30, n_artists=20, artists_per_user=3,
                          friends_per_user=2)
    svc = JoinService(cat)
    q = qs["lastfm_B"]
    svc.frame(q)
    rng = np.random.default_rng(9)
    for i in range(3):
        svc.append("user_artists", {"userID": rng.integers(0, 35, 4),
                                    "artistID": rng.integers(0, 40, 4)})
        svc.append("user_friends", {"userID": rng.integers(0, 35, 3),
                                    "friendID": rng.integers(0, 35, 3)})
        reply = svc.frame(q)
        assert reply.source == "refreshed"
        cold = JoinService(cat, incremental=False)
        assert reply.frame.count() == cold.count(q)


def test_service_falls_back_when_state_missing():
    cat, qs = lastfm_like(n_users=30, n_artists=20, artists_per_user=3,
                          friends_per_user=2)
    svc = JoinService(cat, incremental=False)
    q = qs["lastfm_A1"]
    svc.frame(q)
    svc.append("user_friends", {"userID": [0], "friendID": [1]})
    assert svc.frame(q).source == "computed"       # no state retained


def test_cache_refresh_upgrades_in_place(tmp_path):
    from repro.summary.cache import SummaryCache
    cat, qs = lastfm_like(n_users=30, n_artists=20, artists_per_user=3,
                          friends_per_user=2)
    gfjs = GraphicalJoin(cat, qs["lastfm_tri"]).run()
    cache = SummaryCache(byte_budget=4 << 20, spill_dir=str(tmp_path))
    cache.put("old", gfjs, tables={"user_friends"})
    cache.refresh("old", "new", gfjs, tables={"user_friends"})
    assert "old" not in cache and "new" in cache
    assert cache.stats.refreshes == 1
    # provenance moved with the key: invalidation finds only the new entry
    assert cache.invalidate("user_friends") == 1


def test_feature_provider_survives_live_growth():
    from repro.serve.engine import RelationalFeatureProvider
    cat, qs = lastfm_like(n_users=40, n_artists=30, artists_per_user=4,
                          friends_per_user=3)
    svc = JoinService(cat)
    q = qs["lastfm_A1"]
    prov = RelationalFeatureProvider(
        svc, q, key_var="U1", aggs={"n": "count", "total": ("sum", "A2")})
    keys = np.asarray([0, 1, 2])
    before = prov.features(keys)
    assert prov.features(keys) is not None           # memoized path
    base_requests = svc.stats()["requests"]
    # live growth: hand user 0 a very popular friend
    hot = int(np.argmax(np.bincount(cat["user_artists"]["userID"])))
    svc.append("user_friends", {"userID": [0], "friendID": [hot]})
    after = prov.features(keys)
    assert after[0, 0] > before[0, 0]                # user 0 gained rows
    st = svc.stats()
    assert st["refreshed_requests"] >= 1             # no cold rebuild
    assert st["requests"] > base_requests
