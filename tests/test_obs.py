"""Observability subsystem (DESIGN.md §16): spans, metrics, explain(analyze).

Pins the tracing + metrics contract:

* span nesting is correct across the sharded-build thread pool — shard
  spans are parented to the summarize phase span, per-step elimination
  spans stay inside their own shard (no orphaned or crossed parents);
* the exported Chrome trace passes the `repro.obs.check` validator (the
  same gate CI runs on `benchmarks/run.py --trace` output);
* elimination spans carry product / est / drift annotations;
* metrics snapshots JSON-round-trip through `MetricsRegistry.from_snapshot`;
* `Executor.timings` stays a real dict (legacy equality) while mirroring
  writes into per-phase histograms;
* the disabled-tracing path is a shared no-op whose total cost across a
  pipeline's span call sites is <2% of the untraced pipeline wall.
"""

import json
import threading
import time

import numpy as np
import pytest

from repro.core.api import GraphicalJoin
from repro.ft.straggler import flag_shard_stragglers
from repro.obs.check import validate
from repro.obs.metrics import (REGISTRY, MetricsRegistry, TimingsView)
from repro.obs.trace import (NULL_SPAN, Tracer, ambient_tracer, current_span,
                             span as obs_span, span_in)
from repro.relational.synth import figure1, lastfm_like
from repro.summary.service import JoinService

PARTS = 4


def _lastfm():
    cat, qs = lastfm_like(n_users=200, n_artists=150, artists_per_user=5,
                          friends_per_user=3, alpha=1.3, seed=11)
    return cat, qs["lastfm_A2"]


def _span_index(tracer):
    return {s.span_id: s for s in tracer.spans}


def _ancestors(span, by_id):
    out = []
    pid = span.parent_id
    while pid is not None:
        sp = by_id.get(pid)
        if sp is None:
            break
        out.append(sp)
        pid = sp.parent_id
    return out


# ---------------------------------------------------------------------------
# span mechanics
# ---------------------------------------------------------------------------

def test_nested_spans_parent_via_ambient_context():
    tr = Tracer()
    with tr.span("outer") as outer:
        assert current_span() is outer
        assert ambient_tracer() is tr
        with tr.span("inner") as inner:
            pass
    assert current_span() is None
    assert inner.parent_id == outer.span_id
    assert outer.parent_id is None
    assert outer.t1 >= inner.t1 >= inner.t0 >= outer.t0


def test_ambient_context_does_not_cross_threads():
    """A worker thread starts with no ambient span: its spans are roots
    unless the parent is handed over explicitly (span_in)."""
    tr = Tracer()
    got = {}

    def worker(parent):
        got["ambient"] = current_span()
        with span_in(tr, parent, "child-explicit"):
            pass
        with tr.span("child-implicit"):
            pass

    with tr.span("coordinator") as parent:
        t = threading.Thread(target=worker, args=(parent,))
        t.start()
        t.join()

    assert got["ambient"] is None          # fresh context in the thread
    by_name = {s.name: s for s in tr.spans}
    assert by_name["child-explicit"].parent_id == parent.span_id
    assert by_name["child-implicit"].parent_id is None


def test_disabled_tracing_returns_shared_noop():
    assert obs_span("anything", cat="x", arg=1) is NULL_SPAN
    assert span_in(None, None, "anything") is NULL_SPAN
    with obs_span("anything") as sp:
        assert sp.set(a=1) is sp           # set() is a no-op, chains
    assert ambient_tracer() is None


def test_span_args_mutable_until_export():
    tr = Tracer()
    with tr.span("s", k=1) as sp:
        pass
    sp.set(late=2)                          # annotation after exit is legal
    ev = [e for e in tr.to_chrome_trace()["traceEvents"]
          if e.get("ph") == "X"][0]
    assert ev["args"]["k"] == 1 and ev["args"]["late"] == 2
    # numpy scalars must be coerced to plain JSON types
    sp.set(np_val=np.int64(7))
    doc = tr.to_chrome_trace()
    assert json.loads(json.dumps(doc))      # round-trips through json


# ---------------------------------------------------------------------------
# pipeline span topology (monolithic + shard pool)
# ---------------------------------------------------------------------------

def test_monolithic_pipeline_trace_validates():
    cat, query = figure1()
    tr = Tracer()
    gj = GraphicalJoin(cat, query, tracer=tr)
    gfjs = gj.run()
    gj.desummarize(gfjs)
    names = {s.name for s in tr.spans}
    for phase in ("phase:build_model", "phase:plan", "phase:build_generator",
                  "phase:summarize", "phase:desummarize"):
        assert phase in names, phase
    doc = tr.to_chrome_trace()
    assert validate(doc) == []


def test_eliminate_spans_carry_product_and_drift():
    cat, query = figure1()
    tr = Tracer()
    GraphicalJoin(cat, query, tracer=tr).run()
    elim = tr.find("eliminate")
    assert elim
    for sp in elim:
        assert "product" in sp.args and sp.args["product"] >= 0
        assert "seconds" in sp.args
        if "est" in sp.args:
            assert "drift" in sp.args
    # the planner estimates every step on figure1, so drift must be there
    assert any("drift" in sp.args for sp in elim)
    # parented inside the build_generator phase
    by_id = _span_index(tr)
    gen_phase = tr.find("phase:build_generator")[0]
    for sp in elim:
        assert gen_phase in _ancestors(sp, by_id)


def test_shard_pool_span_topology(tmp_path):
    """Shard spans hang off phase:summarize; every eliminate span inside a
    worker is parented (transitively) to its OWN shard's span — no
    orphans, no crossed parents across pool threads."""
    cat, query = _lastfm()
    tr = Tracer()
    gj = GraphicalJoin(cat, query, partitions=PARTS, tracer=tr)
    gfjs = gj.run()
    assert gfjs.join_size > 0

    by_id = _span_index(tr)
    # no orphaned parents anywhere: every parent_id resolves
    for sp in by_id.values():
        assert sp.parent_id is None or sp.parent_id in by_id, sp.name

    shards = tr.find("shard")
    assert len(shards) == PARTS
    summarize = tr.find("phase:summarize")[0]
    for sp in shards:
        assert sp.parent_id == summarize.span_id
        assert sp.args["shard"] in range(PARTS)
        assert "rows" in sp.args and "wall_seconds" in sp.args
        assert "straggler" in sp.args

    # each eliminate span belongs to exactly one shard, and that shard
    # ran on the same thread (the pool hands one shard to one worker)
    shard_ids = {sp.span_id: sp for sp in shards}
    elim = tr.find("eliminate")
    assert len(elim) >= PARTS            # every shard eliminates something
    for sp in elim:
        anc = _ancestors(sp, by_id)
        owners = [a for a in anc if a.span_id in shard_ids]
        assert len(owners) == 1, f"{sp.name} crosses shard boundaries"
        assert sp.tid == owners[0].tid

    # the exported file passes the CI validator's sharded profile
    path = tr.write_chrome_trace(str(tmp_path / "shard.trace.json"))
    with open(path) as f:
        doc = json.load(f)
    assert validate(doc, expect_shards=True) == []


def test_validator_flags_broken_traces():
    assert validate({"nope": 1}) != []
    assert validate({"traceEvents": []}) != []
    # a trace with phases but no eliminate spans is flagged
    ev = [{"name": f"phase:{p}", "ph": "X", "ts": 0, "dur": 1,
           "pid": 1, "tid": 1, "args": {"span_id": i}}
          for i, p in enumerate(("build_model", "plan", "build_generator",
                                 "summarize"))]
    errs = validate({"traceEvents": ev})
    assert any("eliminate" in e for e in errs)
    # an eliminate span with est but no drift is flagged
    ev2 = ev + [{"name": "eliminate:X", "ph": "X", "ts": 0, "dur": 1,
                 "pid": 1, "tid": 1,
                 "args": {"span_id": 99, "product": 3, "est": 4.0}}]
    errs = validate({"traceEvents": ev2})
    assert any("drift" in e for e in errs)


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_metrics_snapshot_json_roundtrip():
    reg = MetricsRegistry()
    reg.counter("c.events").inc(3)
    reg.gauge("g.skew", unit="x").set(1.75)
    h = reg.histogram("h.lat", unit="s")
    for v in (0.001, 0.002, 0.5, 3.0):
        h.observe(v)
    reg.histogram("h.empty", unit="s")       # never observed: min/max None

    snap = reg.snapshot()
    wire = json.loads(json.dumps(snap))      # must survive JSON transport
    reg2 = MetricsRegistry.from_snapshot(wire)
    assert reg2.snapshot() == snap

    s = snap["h.lat"]
    assert s["count"] == 4 and s["min"] == 0.001 and s["max"] == 3.0
    assert s["sum"] == pytest.approx(3.503)
    assert sum(s["buckets"].values()) == 4
    assert snap["h.empty"]["min"] is None


def test_metrics_registry_kind_mismatch_raises():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(TypeError):
        reg.gauge("x")


def test_timings_view_is_a_legacy_dict_and_mirrors_histograms():
    reg = MetricsRegistry()
    cat, query = figure1()
    gj = GraphicalJoin(cat, query, metrics=reg)
    gj.run()
    t = gj.timings

    # legacy surface: a real dict, equal to its plain-dict copy
    assert isinstance(t, dict)
    assert t == dict(t)
    for key in ("build_model", "plan", "build_generator", "summarize"):
        assert key in t and t[key] >= 0.0

    # every phase write landed in the registry's histogram twin
    snap = reg.snapshot()
    for key in ("build_model", "plan", "build_generator", "summarize"):
        h = snap[f"executor.phase_seconds.{key}"]
        assert h["type"] == "histogram" and h["count"] >= 1
    # external mutation (the GraphicalJoin "aggregate" pattern) mirrors too
    t["aggregate"] = 0.25
    assert reg.snapshot()["executor.phase_seconds.aggregate"]["count"] == 1
    # a non-numeric write keeps dict semantics and skips the mirror
    t["note"] = "not-a-number"
    assert t["note"] == "not-a-number"
    assert "executor.phase_seconds.note" not in reg.snapshot()


def test_build_model_reentry_resets_timings_view():
    reg = MetricsRegistry()
    cat, query = figure1()
    gj = GraphicalJoin(cat, query, metrics=reg)
    gj.run()
    gj.build_model()                          # re-entry clears downstream
    assert "summarize" not in gj.timings
    assert isinstance(gj.timings, TimingsView)   # mirror survives the reset
    # but history in the registry is retained (it is a histogram)
    assert reg.snapshot()["executor.phase_seconds.summarize"]["count"] == 1


# ---------------------------------------------------------------------------
# service + dist metrics
# ---------------------------------------------------------------------------

def test_service_records_latency_and_source_metrics():
    cat, query = figure1()
    svc = JoinService(cat)

    def val(name):
        inst = REGISTRY._instruments.get(name)
        return inst.value if inst is not None else 0.0

    req0 = val("service.requests")
    computed0 = val("service.source.computed")
    memory0 = val("service.source.memory")

    miss = svc.frame(query)
    assert miss.source == "computed"
    assert miss.timings["service"] > 0.0     # latency is on every reply
    hit = svc.frame(query)
    assert hit.source == "memory"
    assert hit.timings["service"] > 0.0      # ... including cache hits

    assert val("service.requests") == req0 + 2
    assert val("service.source.computed") == computed0 + 1
    assert val("service.source.memory") == memory0 + 1
    lat = REGISTRY.snapshot()["service.latency_seconds.memory"]
    assert lat["unit"] == "s" and lat["count"] >= 1
    assert "computed" in miss.explain() and "timings" in miss.explain()


def test_partitioned_run_populates_shard_report_and_gauges():
    reg = MetricsRegistry()
    cat, query = _lastfm()
    gj = GraphicalJoin(cat, query, partitions=PARTS, metrics=reg)
    gj.run()
    rep = gj._executor.shard_report
    assert rep is not None
    assert len(rep["sizes"]) == PARTS and len(rep["seconds"]) == PARTS
    assert len(rep["step_seconds"]) == PARTS          # FULL per-shard matrix
    assert all(isinstance(m, dict) for m in rep["step_seconds"])
    assert rep["skew"] >= 1.0 and rep["time_skew"] >= 1.0
    # step_seconds (max) <= step_seconds_sum, per step, by construction
    ex = gj._executor
    for v, mx in ex.step_seconds.items():
        assert mx <= ex.step_seconds_sum[v] + 1e-12
        col = [m.get(v, 0.0) for m in rep["step_seconds"]]
        assert mx == pytest.approx(max(col))
        assert ex.step_seconds_sum[v] == pytest.approx(sum(col))
    snap = reg.snapshot()
    assert snap["dist.shard_skew"]["value"] == pytest.approx(rep["skew"])
    assert snap["dist.time_skew"]["value"] == pytest.approx(rep["time_skew"])
    assert snap["dist.shard_seconds"]["count"] == PARTS


def test_flag_shard_stragglers_rule():
    assert flag_shard_stragglers([]) == []
    assert flag_shard_stragglers([5.0, 0.1]) == []        # <3 shards: never
    assert flag_shard_stragglers([1.0, 1.0, 1.0, 1.0]) == []
    out = flag_shard_stragglers([1.0, 1.0, 1.0, 10.0])
    assert [s.shard for s in out] == [3]
    assert out[0].ratio == pytest.approx(10.0)
    assert out[0].median == pytest.approx(1.0)
    assert flag_shard_stragglers([0.0, 0.0, 0.0]) == []   # degenerate median


# ---------------------------------------------------------------------------
# explain(analyze=True)
# ---------------------------------------------------------------------------

def test_explain_analyze_renders_per_shard_breakdown():
    cat, query = _lastfm()
    gj = GraphicalJoin(cat, query, partitions=PARTS)
    gj.run()
    text = gj.explain(analyze=True)
    assert "shards:" in text
    for i in range(PARTS):
        assert f"shard {i}" in text
    assert "skew: rows=" in text and "time=" in text
    assert "(max; sum" in text                 # per-step max vs summed work
    # plain explain() keeps the historical shape (no shard section)
    assert "shards:" not in gj.explain()


def test_explain_analyze_monolithic_has_step_times():
    cat, query = figure1()
    gj = GraphicalJoin(cat, query)
    gj.run()
    text = gj.explain(analyze=True)
    assert "eliminate" in text and "est_product=" in text
    assert "time=" in text
    assert "shards:" not in text


# ---------------------------------------------------------------------------
# disabled-tracing overhead (<2% on the plan_bench smoke instance)
# ---------------------------------------------------------------------------

def test_noop_tracer_overhead_under_two_percent():
    """Overhead budget of tracing-off runs, measured structurally: (number
    of span call sites a traced pipeline run exercises) x (cost of one
    no-op span) must stay under 2% of the untraced pipeline wall.  This is
    the deterministic form of the wall-clock A/B (which CI load would
    render flaky) — same instance the planner smoke uses."""
    cat, query = _lastfm()

    # untraced pipeline wall (best of 3 to shed warm-up noise)
    walls = []
    for _ in range(3):
        t0 = time.perf_counter()
        GraphicalJoin(cat, query).run()
        walls.append(time.perf_counter() - t0)
    untraced = min(walls)

    # span call sites exercised by the same pipeline when traced
    tr = Tracer()
    GraphicalJoin(cat, query, tracer=tr).run()
    n_sites = len(tr.spans)
    assert n_sites > 0

    # cost of one disabled span (enter + exit + one set), amortized
    reps = 50_000
    t0 = time.perf_counter()
    for _ in range(reps):
        with obs_span("x") as sp:
            sp.set(a=1)
    per_call = (time.perf_counter() - t0) / reps

    overhead = n_sites * per_call
    assert overhead < 0.02 * untraced, (
        f"no-op tracing would cost {overhead * 1e6:.1f}us across {n_sites} "
        f"span sites vs {untraced * 1e6:.1f}us untraced wall "
        f"({100 * overhead / untraced:.2f}% > 2%)")
